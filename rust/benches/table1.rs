//! T1 — the paper's Table 1: average inference time for style transfer /
//! coloring / super resolution under {unpruned, pruning, pruning+compiler}.
//!
//! Prints (a) measured CPU latency on this machine's native executor —
//! plus the plan's static `peak_bytes`, the **cold-start (warm-up) frame
//! time** of a fresh context (compute-pool spawn + first-touch) next to
//! the steady-state mean, and the *measured* allocations-per-frame of a
//! reusable `ExecContext` (zero in steady state at every thread count,
//! now that kernels fork-join on the persistent pool) — and (b) modeled
//! Adreno-640 latency from the roofline cost model, next to the paper's
//! reported numbers. The reproduction target is the *shape*: ordering,
//! per-stage gains and total speedup band (DESIGN.md §6).
//! Machine-readable `T1-JSON` lines carry latency, memory, warm-up and
//! allocation counts together so the perf trajectory tracks them all
//! (fields documented in docs/BENCH_SCHEMA.md). The pruning+compiler
//! configuration is additionally measured under **auto-tuned schedules**
//! (`--tune`-equivalent; cache in the system temp dir, warm across bench
//! invocations) — the `tuned` / `tuned_speedup` fields and columns
//! compare it against the fixed default schedules — and once more pinned
//! to the **scalar microkernels** (`force_scalar`): the `isa` T1-JSON
//! field records each session's kernel tier and the `simd_speedup`
//! field/column reports scalar-ms / simd-ms, isolating the SIMD
//! contribution on this host — and once more with plan-time operator
//! fusion disabled (`--no-fuse`-equivalent): the `fused_steps` field
//! counts compound conv+bias+act(+add) steps in each session's plan, and
//! the `fusion_speedup` field/column reports unfused-ms / fused-ms; the
//! unfused line's `memory` block also exposes the arena growth from
//! materializing fused intermediates — and once more under **int8
//! quantization** (`--int8`-equivalent): the `int8_ms` / `int8_speedup`
//! fields compare the per-channel i8 kernels against the f32 compact
//! time, and `int8_max_err` records the measured max-abs deviation from
//! the f32 outputs (the error-bounded second oracle;
//! docs/ARCHITECTURE.md §Quantization). A **T1c** table measures batched
//! steady-state throughput (`--batch N`, default 4) under auto-tuned
//! schedules (batched plans tune their real batch-N dispatch geometry):
//! the pruning+compiler engine compiled at batch N runs N frames per
//! dispatch, reported as frames/s next to the batch-1 engine, with
//! allocs/frame still zero (`batch` / `fps` T1-JSON fields).

use prt_dnn::apps::{build_app, prune_graph, AppSpec, Variant};
use prt_dnn::bench::{bench_auto_ms, bytes, mem_json, ms, speedup, summary_json, Table};
use prt_dnn::executor::{ExecContext, ExecutionPlan};
use prt_dnn::passes::PassManager;
use prt_dnn::perfmodel::{estimate_graph, Device, VariantKind};
use prt_dnn::session::{Model, Quantization, Session};
use prt_dnn::tensor::Tensor;
use prt_dnn::tuner::TuneOpts;
use prt_dnn::util::alloc_count::{alloc_count, CountingAlloc};
use prt_dnn::util::json::{Json, JsonObj};
use std::time::Instant;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Session for one (app, variant) cell of the table.
#[allow(clippy::too_many_arguments)]
fn session_for(
    app: &str,
    variant: Variant,
    width: f64,
    threads: usize,
    batch: usize,
    tune: TuneOpts,
    force_scalar: bool,
    fuse: bool,
    quantize: Quantization,
) -> anyhow::Result<Session> {
    Model::for_app_scaled(app, variant, width, 42)?
        .session()
        .threads(threads)
        .batch(batch)
        .tune(tune)
        .force_scalar(force_scalar)
        .fuse(fuse)
        .quantize(quantize)
        .build()
}

/// Warm tune-cache path shared by the tuned T1a cell and the T1c batched
/// table (batched plans key their schedules by batch, so one file per
/// (app, width, threads) serves every batch).
fn tune_cache_path(app: &str, width: f64, threads: usize) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("prt-dnn-tune-{}-w{}-t{}.json", app, width, threads))
}

/// Measured heap allocations per frame of a warm, single-context
/// `run_into` loop. Zero for the planned executor at every thread count:
/// kernels dispatch on the context's persistent compute pool, so no
/// per-frame thread spawns show up in the counter.
fn allocs_per_frame(plan: &ExecutionPlan, x: &Tensor, frames: usize) -> f64 {
    let mut ctx = ExecContext::for_plan(plan);
    let mut outs: Vec<Tensor> =
        plan.output_shapes().iter().map(|s| Tensor::zeros(s)).collect();
    let _ = ctx.run_into(plan, std::slice::from_ref(x), &mut outs);
    let before = alloc_count();
    for _ in 0..frames {
        let _ = ctx.run_into(plan, std::slice::from_ref(x), &mut outs);
    }
    (alloc_count() - before) as f64 / frames as f64
}

/// Cold-start cost of a fresh context: pool spawn + arena/scratch
/// allocation + first frame (first-touch page faults), in ms.
fn warmup_frame_ms(plan: &ExecutionPlan, x: &Tensor) -> f64 {
    let t0 = Instant::now();
    let mut ctx = ExecContext::for_plan(plan);
    let mut outs: Vec<Tensor> =
        plan.output_shapes().iter().map(|s| Tensor::zeros(s)).collect();
    let _ = ctx.run_into(plan, std::slice::from_ref(x), &mut outs);
    t0.elapsed().as_secs_f64() * 1e3
}

const PAPER: &[(&str, [f64; 3])] = &[
    ("style", [283.0, 178.0, 67.0]),
    ("coloring", [137.0, 85.0, 38.0]),
    ("sr", [269.0, 192.0, 73.0]),
];

fn main() -> anyhow::Result<()> {
    let threads = prt_dnn::util::num_threads();
    let argv: Vec<String> = std::env::args().collect();
    let quick = argv.iter().any(|a| a == "--quick");
    // `--batch N` sets the fused-frames column of the T1c batched
    // throughput table (default 4; batch 1 is always measured alongside,
    // so N must be >= 2 — a clamped or unparseable value is reported).
    let batch_req = argv
        .iter()
        .position(|a| a == "--batch")
        .and_then(|i| argv.get(i + 1))
        .map(|v| v.parse::<usize>());
    let batch_n = match &batch_req {
        Some(Ok(n)) => (*n).max(2),
        Some(Err(_)) => 4,
        None => 4,
    };
    match batch_req {
        Some(Ok(n)) if n < 2 => {
            eprintln!("table1: --batch {} clamped to {} (batch 1 is always measured)", n, batch_n)
        }
        Some(Err(_)) => eprintln!("table1: unparseable --batch value, using {}", batch_n),
        _ => {}
    }
    let width = if quick { 0.25 } else { 1.0 };
    let budget = if quick { 300.0 } else { 1500.0 };
    let alloc_frames = if quick { 3 } else { 10 };

    // (a) measured on the native executor.
    let mut measured = Table::new(
        format!(
            "T1a measured CPU ms (native executor, width={}, {} threads)",
            width, threads
        ),
        &[
            "app",
            "unpruned",
            "pruning",
            "pruning+compiler",
            "speedup",
            "peak",
            "warmup",
            "allocs/frame",
            "tuned ms",
            "tuned_speedup",
            "isa",
            "scalar ms",
            "simd_speedup",
            "fused steps",
            "no-fuse ms",
            "fusion_speedup",
            "int8 ms",
            "int8_speedup",
            "int8 max err",
        ],
    );
    let mut json_lines: Vec<Json> = Vec::new();
    for (app, _) in PAPER {
        let mut row = Vec::new();
        let mut base = 0.0;
        let mut last = 0.0;
        let mut peak = 0usize;
        let mut apf = 0.0f64;
        let mut warm = 0.0f64;
        let mut isa_tag = "scalar";
        let mut fused_steps = 0usize;
        for variant in Variant::table1() {
            let session = session_for(
                app,
                variant,
                width,
                threads,
                1,
                TuneOpts::off(),
                false,
                true,
                Quantization::None,
            )?;
            let shape = session.shapes().inputs[0].clone();
            let x = Tensor::full(&shape, 0.5);
            // Cold start first: fresh context = pool spawn + first frame.
            let warm_ms = warmup_frame_ms(session.plan(), &x);
            let s = bench_auto_ms(budget, || {
                let _ = session.run(std::slice::from_ref(&x)).unwrap();
            });
            // Alloc accounting at the full thread count: the persistent
            // pool keeps the steady state allocation-free even at
            // threads > 1 (the old scoped-spawn executor could not).
            let variant_apf = allocs_per_frame(session.plan(), &x, alloc_frames);
            if variant == Variant::Unpruned {
                base = s.mean;
            }
            last = s.mean;
            row.push(ms(s.mean));
            if variant == Variant::PrunedCompiler {
                peak = session.memory().peak_bytes;
                apf = variant_apf;
                warm = warm_ms;
                isa_tag = session.isa().tag();
                fused_steps = session.fused_steps();
            }
            let mut j = JsonObj::new();
            j.insert("app", app.to_string());
            j.insert("variant", variant.name());
            j.insert("threads", threads);
            j.insert("batch", 1usize);
            j.insert("latency", summary_json(&s));
            j.insert("memory", mem_json(&session.memory()));
            j.insert("warmup_ms", warm_ms);
            j.insert("allocs_per_frame", variant_apf);
            j.insert("tuned", false);
            j.insert("isa", session.isa().tag());
            j.insert("fused_steps", session.fused_steps());
            json_lines.push(Json::Obj(j));
        }
        // Pruning+compiler once more under auto-tuned schedules. The
        // cache lives in the temp dir, so repeated bench invocations plan
        // without a single micro-benchmark run.
        let tune_path = tune_cache_path(app, width, threads);
        let tuned = session_for(
            app,
            Variant::PrunedCompiler,
            width,
            threads,
            1,
            TuneOpts::on(&tune_path),
            false,
            true,
            Quantization::None,
        )?;
        let tx = Tensor::full(&tuned.shapes().inputs[0], 0.5);
        let ts = bench_auto_ms(budget, || {
            let _ = tuned.run(std::slice::from_ref(&tx)).unwrap();
        });
        let tuned_speedup = last / ts.mean.max(1e-9);
        let tstats = tuned.plan().tune_stats();
        let mut j = JsonObj::new();
        j.insert("app", app.to_string());
        j.insert("variant", Variant::PrunedCompiler.name());
        j.insert("threads", threads);
        j.insert("batch", 1usize);
        j.insert("latency", summary_json(&ts));
        j.insert("memory", mem_json(&tuned.memory()));
        j.insert("tuned", true);
        j.insert("tuned_speedup", tuned_speedup);
        j.insert("tune_bench_runs", tstats.bench_runs);
        j.insert("isa", tuned.isa().tag());
        j.insert("fused_steps", tuned.fused_steps());
        json_lines.push(Json::Obj(j));

        // Pruning+compiler once more pinned to the scalar microkernels:
        // scalar-ms / simd-ms isolates the SIMD tier's contribution (1.0
        // by construction on a scalar-only host).
        let scalar = session_for(
            app,
            Variant::PrunedCompiler,
            width,
            threads,
            1,
            TuneOpts::off(),
            true,
            true,
            Quantization::None,
        )?;
        let sx = Tensor::full(&scalar.shapes().inputs[0], 0.5);
        let ss = bench_auto_ms(budget, || {
            let _ = scalar.run(std::slice::from_ref(&sx)).unwrap();
        });
        let simd_speedup = ss.mean / last.max(1e-9);
        let mut j = JsonObj::new();
        j.insert("app", app.to_string());
        j.insert("variant", Variant::PrunedCompiler.name());
        j.insert("threads", threads);
        j.insert("batch", 1usize);
        j.insert("latency", summary_json(&ss));
        j.insert("memory", mem_json(&scalar.memory()));
        j.insert("tuned", false);
        j.insert("isa", scalar.isa().tag());
        j.insert("force_scalar", true);
        j.insert("simd_speedup", simd_speedup);
        j.insert("fused_steps", scalar.fused_steps());
        json_lines.push(Json::Obj(j));

        // Pruning+compiler once more with plan-time fusion disabled:
        // unfused-ms / fused-ms isolates the fusion pass's contribution,
        // and the unfused memory block shows the arena paid for
        // materializing the absorbed intermediates.
        let nofuse = session_for(
            app,
            Variant::PrunedCompiler,
            width,
            threads,
            1,
            TuneOpts::off(),
            false,
            false,
            Quantization::None,
        )?;
        let fx = Tensor::full(&nofuse.shapes().inputs[0], 0.5);
        let fs = bench_auto_ms(budget, || {
            let _ = nofuse.run(std::slice::from_ref(&fx)).unwrap();
        });
        let fusion_speedup = fs.mean / last.max(1e-9);
        let mut j = JsonObj::new();
        j.insert("app", app.to_string());
        j.insert("variant", Variant::PrunedCompiler.name());
        j.insert("threads", threads);
        j.insert("batch", 1usize);
        j.insert("latency", summary_json(&fs));
        j.insert("memory", mem_json(&nofuse.memory()));
        j.insert("tuned", false);
        j.insert("isa", nofuse.isa().tag());
        j.insert("no_fuse", true);
        j.insert("fused_steps", nofuse.fused_steps());
        j.insert("fusion_speedup", fusion_speedup);
        json_lines.push(Json::Obj(j));

        // Pruning+compiler once more under int8 quantization: i8 weights
        // are ¼ the traffic of f32 on the memory-bound sparse kernels, so
        // int8-ms should at worst match the f32 compact time. The
        // `int8_max_err` field records the measured max-abs deviation from
        // the f32 session on the same input (bounded by
        // `perfmodel::int8_error_bound`; int8 has no bitwise-vs-f32
        // oracle — see docs/ARCHITECTURE.md §Quantization).
        let int8 = session_for(
            app,
            Variant::PrunedCompiler,
            width,
            threads,
            1,
            TuneOpts::off(),
            false,
            true,
            Quantization::Int8,
        )?;
        let f32_ref = session_for(
            app,
            Variant::PrunedCompiler,
            width,
            threads,
            1,
            TuneOpts::off(),
            false,
            true,
            Quantization::None,
        )?;
        let qx = Tensor::full(&int8.shapes().inputs[0], 0.5);
        let qwant = f32_ref.run(std::slice::from_ref(&qx))?;
        let qgot = int8.run(std::slice::from_ref(&qx))?;
        let int8_max_err = qwant
            .iter()
            .zip(qgot.iter())
            .flat_map(|(a, b)| a.data().iter().zip(b.data()))
            .map(|(&x, &y)| (x as f64 - y as f64).abs())
            .fold(0.0f64, f64::max);
        let qs = bench_auto_ms(budget, || {
            let _ = int8.run(std::slice::from_ref(&qx)).unwrap();
        });
        let int8_speedup = last / qs.mean.max(1e-9);
        let mut j = JsonObj::new();
        j.insert("app", app.to_string());
        j.insert("variant", Variant::PrunedCompiler.name());
        j.insert("threads", threads);
        j.insert("batch", 1usize);
        j.insert("latency", summary_json(&qs));
        j.insert("memory", mem_json(&int8.memory()));
        j.insert("tuned", false);
        j.insert("isa", int8.isa().tag());
        j.insert("quantize", "int8");
        j.insert("int8_ms", qs.mean);
        j.insert("int8_speedup", int8_speedup);
        j.insert("int8_max_err", int8_max_err);
        j.insert("fused_steps", int8.fused_steps());
        json_lines.push(Json::Obj(j));

        row.insert(0, app.to_string());
        row.push(speedup(base, last));
        row.push(bytes(peak));
        row.push(ms(warm));
        row.push(format!("{:.1}", apf));
        row.push(ms(ts.mean));
        row.push(format!("{:.2}x", tuned_speedup));
        row.push(isa_tag.to_string());
        row.push(ms(ss.mean));
        row.push(format!("{:.2}x", simd_speedup));
        row.push(format!("{}", fused_steps));
        row.push(ms(fs.mean));
        row.push(format!("{:.2}x", fusion_speedup));
        row.push(ms(qs.mean));
        row.push(format!("{:.2}x", int8_speedup));
        row.push(format!("{:.3}", int8_max_err));
        measured.row(&row);
    }
    measured.print();

    // (c) batched steady-state throughput: the pruning+compiler engine at
    // batch 1 vs batch N. Batching amortises per-dispatch overhead and
    // lets small layers split across N × rows, so frames/s should rise
    // with allocs/frame staying 0.
    let mut batched = Table::new(
        format!(
            "T1c batched throughput (pruning+compiler, tuned, width={}, {} threads, frames/s)",
            width, threads
        ),
        &["app", "fps b=1", "fps b=N", "N", "speedup", "allocs/frame b=N"],
    );
    for (app, _) in PAPER {
        let mut fps1 = 0.0f64;
        let mut fps_n = 0.0f64;
        let mut apf_n = 0.0f64;
        // Batched plans tune their real batch-N dispatch geometry (the
        // cache key carries the batch), sharing T1a's warm cache file.
        let tune_path = tune_cache_path(app, width, threads);
        for &b in &[1usize, batch_n] {
            let session = session_for(
                app,
                Variant::PrunedCompiler,
                width,
                threads,
                b,
                TuneOpts::on(&tune_path),
                false,
                true,
                Quantization::None,
            )?;
            let x = Tensor::full(&session.shapes().inputs[0], 0.5);
            let s = bench_auto_ms(budget, || {
                let _ = session.run(std::slice::from_ref(&x)).unwrap();
            });
            let fps = b as f64 * 1e3 / s.mean.max(1e-9);
            let apf = allocs_per_frame(session.plan(), &x, alloc_frames) / b as f64;
            if b == 1 {
                fps1 = fps;
            } else {
                fps_n = fps;
                apf_n = apf;
            }
            let mut j = JsonObj::new();
            j.insert("app", app.to_string());
            j.insert("variant", Variant::PrunedCompiler.name());
            j.insert("threads", threads);
            j.insert("batch", b);
            j.insert("latency", summary_json(&s));
            j.insert("memory", mem_json(&session.memory()));
            j.insert("fps", fps);
            j.insert("allocs_per_frame", apf);
            j.insert("tuned", true);
            j.insert("tune_bench_runs", session.plan().tune_stats().bench_runs);
            j.insert("isa", session.isa().tag());
            j.insert("fused_steps", session.fused_steps());
            json_lines.push(Json::Obj(j));
        }
        batched.row(&[
            app.to_string(),
            format!("{:.1}", fps1),
            format!("{:.1}", fps_n),
            format!("{}", batch_n),
            format!("{:.2}x", fps_n / fps1.max(1e-9)),
            format!("{:.1}", apf_n),
        ]);
    }
    batched.print();

    for line in &json_lines {
        println!("T1-JSON {}", line);
    }

    // (b) modeled on the paper's device.
    let device = Device::adreno640();
    let model_width = 2.8; // analytic only: paper-scale channel counts
    let mut modeled = Table::new(
        format!("T1b modeled Adreno-640 ms (roofline, width={})", model_width),
        &["app", "unpruned", "pruning", "pruning+compiler", "speedup", "paper"],
    );
    for (app, paper) in PAPER {
        let g = build_app(app, model_width, 42)?;
        let spec = AppSpec::for_app(app);
        let (t_dense, _) = estimate_graph(&g, &device, VariantKind::DenseUnfused, &[])?;
        let mut pruned = g.clone();
        let schemes = prune_graph(&mut pruned, &spec);
        let (t_csr, _) = estimate_graph(&pruned, &device, VariantKind::CsrUnfused, &schemes)?;
        let mut fused = pruned.clone();
        PassManager::default().run_fixpoint(&mut fused, 4);
        let (t_c, _) = estimate_graph(&fused, &device, VariantKind::CompactFused, &schemes)?;
        modeled.row(&[
            app.to_string(),
            ms(t_dense * 1e3),
            ms(t_csr * 1e3),
            ms(t_c * 1e3),
            speedup(t_dense * 1e3, t_c * 1e3),
            format!(
                "{}/{}/{} = {:.1}x",
                paper[0], paper[1], paper[2], paper[0] / paper[2]
            ),
        ]);
    }
    modeled.print();
    println!(
        "\nshape check: pruning row < unpruned, compiler row < pruning row, total speedup in the 2.5-5x band."
    );
    Ok(())
}
