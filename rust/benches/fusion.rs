//! D1 — §3 "DSL related optimization": per-pass ablation. Measures each
//! app end-to-end under {no passes, +fold_bn, +fuse_activation, full}
//! with pruned compact weights, isolating the graph-transformation gain.

use prt_dnn::apps::{build_app, prune_graph, AppSpec};
use prt_dnn::bench::{bench_auto_ms, ms, Table};
use prt_dnn::passes::PassManager;
use prt_dnn::session::Model;
use prt_dnn::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let threads = prt_dnn::util::num_threads();
    let width = 0.5;
    let pipelines: &[(&str, Vec<&str>)] = &[
        ("none", vec![]),
        ("+fold_bn", vec!["fold_bn"]),
        ("+fuse_act", vec!["fuse_activation"]),
        ("full", vec!["fold_bn", "fuse_activation", "dce"]),
    ];

    let mut t = Table::new(
        format!("D1 pass-pipeline ablation (pruned+compact, width={}, ms)", width),
        &["app", "none", "+fold_bn", "+fuse_act", "full", "nodes none->full"],
    );
    for app in ["style", "coloring", "sr"] {
        let mut base = build_app(app, width, 42)?;
        let spec = AppSpec::for_app(app);
        let schemes = prune_graph(&mut base, &spec);
        let mut row = vec![app.to_string()];
        let mut nodes_before = 0;
        let mut nodes_after = 0;
        for (i, (_, passes)) in pipelines.iter().enumerate() {
            let mut g = base.clone();
            PassManager::with(passes).run_fixpoint(&mut g, 4);
            if i == 0 {
                nodes_before = g.len();
            }
            nodes_after = g.len();
            // The pass ablation transforms the graph by hand, so the
            // session wraps the already-lowered graph + schemes.
            let session = Model::from_compiled(g, schemes.clone())
                .session()
                .threads(threads)
                .build()?;
            let shape = session.shapes().inputs[0].clone();
            let x = Tensor::full(&shape, 0.5);
            let s = bench_auto_ms(700.0, || {
                let _ = session.run(std::slice::from_ref(&x)).unwrap();
            });
            row.push(ms(s.mean));
        }
        row.push(format!("{}->{}", nodes_before, nodes_after));
        t.row(&row);
    }
    t.print();
    println!(
        "\nclaim check: every pass monotonically reduces node count (coloring 34->18). On this \
         no-launch-overhead CPU the wall-clock effect is within noise; the mobile cost model \
         (integration test fusion_reduces_modeled_data_movement) carries the data-movement claim."
    );
    Ok(())
}
