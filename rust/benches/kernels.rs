//! K-micro — kernel microbenchmarks: dense GEMM GFLOP/s by shape, thread
//! count and microkernel ISA (scalar vs the detected SIMD tier, order-
//! preserving and relaxed-FMA flavors, narrow and wide register tiles),
//! plus conv tiers (dense / CSR / column-compact / reordered) on a
//! representative layer, plus the int8 GEMM (i8×i8→i32 + requantize)
//! against its f32 counterpart — with an exactness sweep over odd shapes
//! and unaligned tails pinning the SIMD i8 kernels to a scalar integer
//! reference. Feeds the §Perf iteration log.

use prt_dnn::bench::{bench_ms, ms, Table};
use prt_dnn::dsl::op::{Activation, PadMode};
use prt_dnn::kernels::conv::{
    conv2d_column_compact, conv2d_csr, conv2d_dense, conv2d_reordered, ConvScratch,
};
use prt_dnn::kernels::gemm::{gemm, gemm_with};
use prt_dnn::kernels::im2col::ConvGeom;
use prt_dnn::kernels::micro::{self, Isa};
use prt_dnn::kernels::qgemm::{qgemm_batch, requantize};
use prt_dnn::quant::{quantize_act, QDense};
use prt_dnn::pruning::scheme::{project_scheme, Scheme};
use prt_dnn::pruning::verify::apply_mask;
use prt_dnn::reorder::{ReorderPlan, Schedule as LaneSchedule};
use prt_dnn::sparse::{ColumnCompact, Csr, GemmView};
use prt_dnn::tensor::Tensor;
use prt_dnn::tuner::Schedule;
use prt_dnn::util::rng::Rng;
use prt_dnn::util::threadpool::ComputePool;

fn main() {
    let mut rng = Rng::new(23);
    let max_threads = prt_dnn::util::num_threads();

    // Dense GEMM GFLOP/s.
    let mut t = Table::new(
        "K-micro dense GEMM",
        &["M", "K", "N", "threads", "ms", "GFLOP/s"],
    );
    for &(m, k, n) in &[(64, 576, 4096), (128, 1152, 4096), (32, 288, 16384)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        for threads in [1, max_threads] {
            let pool = ComputePool::new(threads);
            let mut c = vec![0.0f32; m * n];
            let s = bench_ms(2, 8, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                gemm(m, k, n, &a, &b, &mut c, &pool);
            });
            let gflops = 2.0 * (m * k * n) as f64 / (s.mean / 1e3) / 1e9;
            t.row(&[
                format!("{}", m),
                format!("{}", k),
                format!("{}", n),
                format!("{}", threads),
                ms(s.mean),
                format!("{:.2}", gflops),
            ]);
        }
    }
    t.print();

    // Microkernel ISA sweep: the same GEMM under scalar, the detected
    // order-preserving SIMD tier (narrow 2×8 and wide 4×16 register
    // tiles) and the relaxed-FMA flavor. On a scalar-only host (or under
    // PALLAS_FORCE_SCALAR) every row collapses to the scalar kernel.
    let det = micro::detect();
    let mut t = Table::new(
        format!("K-micro GEMM microkernel ISA sweep (detected: {})", det.tag()),
        &["M", "K", "N", "threads", "isa", "mr x nr", "relaxed", "ms", "GFLOP/s", "vs scalar"],
    );
    let mut flavors: Vec<(Isa, usize, usize, bool)> = vec![(Isa::Scalar, 2, 8, false)];
    if det != Isa::Scalar {
        flavors.push((det, 2, 8, false));
        flavors.push((det, 4, 16, false));
        flavors.push((det, 4, 16, true));
    }
    for &(m, k, n) in &[(64, 576, 4096), (128, 1152, 4096)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        for threads in [1, max_threads] {
            let pool = ComputePool::new(threads);
            let mut scalar_ms = 0.0f64;
            for &(isa, mr, nr, relaxed) in &flavors {
                let sched = Schedule { isa, mr, nr, relaxed, ..Schedule::default() };
                let mut c = vec![0.0f32; m * n];
                let s = bench_ms(2, 8, || {
                    c.iter_mut().for_each(|v| *v = 0.0);
                    gemm_with(m, k, n, &a, &b, &mut c, &pool, &sched);
                });
                if isa == Isa::Scalar {
                    scalar_ms = s.mean;
                }
                let gflops = 2.0 * (m * k * n) as f64 / (s.mean / 1e3) / 1e9;
                t.row(&[
                    format!("{}", m),
                    format!("{}", k),
                    format!("{}", n),
                    format!("{}", threads),
                    isa.tag().to_string(),
                    format!("{}x{}", mr, nr),
                    format!("{}", relaxed),
                    ms(s.mean),
                    format!("{:.2}", gflops),
                    format!("{:.2}x", scalar_ms / s.mean.max(1e-9)),
                ]);
            }
        }
    }
    t.print();

    // Conv execution tiers on one layer: 64x32x3x3 over 64x64.
    let (o, ic, hw) = (64, 32, 64);
    let x = Tensor::randn(&[1, ic, hw, hw], &mut rng);
    let w = Tensor::randn(&[o, ic, 3, 3], &mut rng);
    let geom = ConvGeom::new(ic, hw, hw, 3, 1, 1);
    let mut scratch = ConvScratch::new();
    let mut out = vec![0.0f32; o * geom.out_px()];
    let threads = max_threads;
    let pool = ComputePool::new(threads);

    let mut t = Table::new(
        format!("K-micro conv tiers (64x32x3x3 @ {0}x{0}, {1} threads)", hw, threads),
        &["tier", "sparsity", "ms", "vs dense"],
    );
    let sched = Schedule::default();
    let dense_s = bench_ms(2, 8, || {
        conv2d_dense(
            x.data(), 1, &w, &geom, PadMode::Zeros, None, Activation::Identity, &pool,
            &mut scratch, &sched, None, &mut out,
        );
    });
    t.row(&["dense".into(), "0%".into(), ms(dense_s.mean), "1.00x".into()]);

    for kind in ["column", "pattern"] {
        let s = project_scheme(&w, kind, 0.7, None);
        let wp = apply_mask(&w, &s);
        let gv = GemmView::from_oihw(&wp);
        let sparsity = 1.0 - gv.nnz() as f64 / (gv.rows * gv.cols) as f64;

        let csr = Csr::from_dense(&gv);
        let csr_s = bench_ms(2, 8, || {
            conv2d_csr(
                x.data(), 1, &csr, &geom, PadMode::Zeros, None, Activation::Identity,
                &pool, &mut scratch, &sched, None, &mut out,
            );
        });
        t.row(&[
            format!("csr/{}", kind),
            format!("{:.0}%", sparsity * 100.0),
            ms(csr_s.mean),
            format!("{:.2}x", dense_s.mean / csr_s.mean),
        ]);

        let fast = if let Scheme::Column { keep } = &s {
            let cc = ColumnCompact::encode(&gv, keep);
            bench_ms(2, 8, || {
                conv2d_column_compact(
                    x.data(), 1, &cc, &geom, PadMode::Zeros, None, Activation::Identity,
                    &pool, &mut scratch, &sched, None, &mut out,
                );
            })
        } else {
            let plan = ReorderPlan::build(&gv);
            let lanes = LaneSchedule::build(&plan, threads);
            bench_ms(2, 8, || {
                conv2d_reordered(
                    x.data(), 1, &plan, &lanes, &geom, PadMode::Zeros, None,
                    Activation::Identity, &pool, &mut scratch, &sched, None, &mut out,
                );
            })
        };
        t.row(&[
            format!("compact/{}", kind),
            format!("{:.0}%", sparsity * 100.0),
            ms(fast.mean),
            format!("{:.2}x", dense_s.mean / fast.mean),
        ]);
    }
    t.print();

    // Int8 exactness sweep: odd shapes and unaligned tails (the same
    // shapes that pin the f32 microkernels) — the detected-ISA i8 kernels
    // must agree with a scalar integer reference to the last bit, since
    // i8×i8→i32 accumulation has no rounding to hide behind.
    let pool = ComputePool::new(max_threads);
    let scalar_sched = Schedule::default(); // default ISA is Scalar
    let native_sched = Schedule { isa: micro::detect(), ..Schedule::default() }.sanitized();
    for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 31, 33), (64, 100, 130), (5, 576, 999)] {
        let af: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let bf: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let aw = Tensor::from_vec(&[m, k, 1, 1], af.clone());
        let qa = QDense::from_view(&GemmView::from_oihw(&aw));
        let mut qb = vec![0i8; k * n];
        let xscale = quantize_act(&bf, &mut qb);

        // Scalar integer reference.
        let mut want = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for r in 0..k {
                    acc += qa.values[i * k + r] as i32 * qb[r * n + j] as i32;
                }
                want[i * n + j] = acc;
            }
        }
        for sched in [&scalar_sched, &native_sched] {
            let mut got = vec![0i32; m * n];
            qgemm_batch(1, m, k, n, &qa, &qb, &mut got, &pool, sched);
            assert_eq!(
                want, got,
                "i8 GEMM {}x{}x{} diverged from the scalar reference ({})",
                m, k, n, sched.isa.tag()
            );
        }
        // Requantize lands within the analytical dot-product bound of the
        // true f32 product.
        let mut qf = vec![0.0f32; m * n];
        requantize(&want, &qa.scales, &[xscale], m, n, &mut qf, &pool);
        let wmax = af.iter().fold(0.0f32, |mx, &v| mx.max(v.abs())) as f64;
        let xmax = bf.iter().fold(0.0f32, |mx, &v| mx.max(v.abs())) as f64;
        let bound = prt_dnn::perfmodel::dot_error_bound(k, wmax, xmax);
        for i in 0..m {
            for j in 0..n {
                let exact: f64 = (0..k)
                    .map(|r| af[i * k + r] as f64 * bf[r * n + j] as f64)
                    .sum();
                let err = (exact - qf[i * n + j] as f64).abs();
                assert!(
                    err <= bound,
                    "requantized {}x{}x{} [{},{}]: err {} > bound {}",
                    m, k, n, i, j, err, bound
                );
            }
        }
    }

    // Int8 GEMM throughput vs f32 on the headline shapes.
    let mut t = Table::new(
        format!("K-micro int8 GEMM ({} threads)", max_threads),
        &["M", "K", "N", "f32 ms", "i8 ms", "i8 vs f32"],
    );
    for &(m, k, n) in &[(64, 576, 4096), (128, 1152, 4096)] {
        let af: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let bf: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let aw = Tensor::from_vec(&[m, k, 1, 1], af.clone());
        let qa = QDense::from_view(&GemmView::from_oihw(&aw));
        let mut qb = vec![0i8; k * n];
        let xscale = quantize_act(&bf, &mut qb);
        let mut c = vec![0.0f32; m * n];
        let f32_s = bench_ms(2, 8, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            gemm(m, k, n, &af, &bf, &mut c, &pool);
        });
        let mut acc = vec![0i32; m * n];
        let mut qo = vec![0.0f32; m * n];
        let i8_s = bench_ms(2, 8, || {
            acc.iter_mut().for_each(|v| *v = 0);
            qgemm_batch(1, m, k, n, &qa, &qb, &mut acc, &pool, &native_sched);
            requantize(&acc, &qa.scales, &[xscale], m, n, &mut qo, &pool);
        });
        t.row(&[
            format!("{}", m),
            format!("{}", k),
            format!("{}", n),
            ms(f32_s.mean),
            ms(i8_s.mean),
            format!("{:.2}x", f32_s.mean / i8_s.mean.max(1e-9)),
        ]);
    }
    t.print();
}
