//! R1 — §3 "Matrix reorder": grouping filters with similar patterns and
//! compacting columns fixes load imbalance + irregular access. Measures
//! (a) the load-imbalance metric and (b) actual sparse GEMM wall time,
//! CSR-without-reorder vs reordered, across thread counts.

use prt_dnn::bench::{bench_ms, ms, Table};
use prt_dnn::kernels::sparse_gemm::{reordered_panel_len, spmm_csr, spmm_reordered};
use prt_dnn::pruning::scheme::project_scheme;
use prt_dnn::pruning::verify::apply_mask;
use prt_dnn::reorder::schedule::naive_row_loads;
use prt_dnn::reorder::{load_imbalance, ReorderPlan, Schedule as LaneSchedule};
use prt_dnn::sparse::{Csr, GemmView};
use prt_dnn::tensor::Tensor;
use prt_dnn::tuner::Schedule;
use prt_dnn::util::rng::Rng;
use prt_dnn::util::threadpool::ComputePool;

fn main() {
    let mut rng = Rng::new(17);
    // A pattern-pruned layer shaped like the SR expand conv at width 1.0,
    // with extra connectivity skew to stress load balance.
    let (o, i) = (96, 48);
    let w = Tensor::randn(&[o, i, 3, 3], &mut rng);
    let s = project_scheme(&w, "pattern", 0.7, None);
    let mut wp = apply_mask(&w, &s);
    // Skew: zero out most kernels of the second half of filters (uneven nnz
    // per row, the worst case for block-row CSR parallelism).
    {
        let cols = i * 9;
        let data = wp.data_mut();
        for r in o / 2..o {
            for c in 0..cols {
                if c % 4 != 0 {
                    data[r * cols + c] = 0.0;
                }
            }
        }
    }
    let gv = GemmView::from_oihw(&wp);
    let csr = Csr::from_dense(&gv);
    let plan = ReorderPlan::build(&gv);
    let n = 32 * 32; // output pixels
    let b: Vec<f32> = (0..gv.cols * n).map(|_| rng.normal()).collect();

    let mut t = Table::new(
        format!(
            "R1 sparse GEMM {}x{} (nnz={}, groups={}) x [{}x{}]",
            gv.rows,
            gv.cols,
            gv.nnz(),
            plan.group_count(),
            gv.cols,
            n
        ),
        &["threads", "imbalance CSR", "imbalance reorder", "CSR ms", "reorder ms", "speedup"],
    );
    let tuned = Schedule::default();
    for threads in [1usize, 2, 4, 8] {
        let pool = ComputePool::new(threads);
        let lanes = LaneSchedule::build(&plan, threads);
        let imb_naive = load_imbalance(&naive_row_loads(&csr.row_nnz(), threads));
        let imb_ro = load_imbalance(&lanes.loads());

        let mut c1 = vec![0.0f32; gv.rows * n];
        let csr_t = bench_ms(2, 12, || {
            c1.iter_mut().for_each(|v| *v = 0.0);
            spmm_csr(&csr, &b, n, &mut c1, &pool, &tuned);
        });
        let mut c2 = vec![0.0f32; gv.rows * n];
        let mut panel = vec![0.0f32; reordered_panel_len(&plan, n, pool.threads())];
        let ro_t = bench_ms(2, 12, || {
            c2.iter_mut().for_each(|v| *v = 0.0);
            spmm_reordered(&plan, &lanes, &b, n, &mut c2, &pool, &mut panel, &tuned);
        });
        // Same math.
        let err: f32 = c1
            .iter()
            .zip(c2.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-3, "reorder changed results: {}", err);

        t.row(&[
            format!("{}", threads),
            format!("{:.2}", imb_naive),
            format!("{:.2}", imb_ro),
            ms(csr_t.mean),
            ms(ro_t.mean),
            format!("{:.2}x", csr_t.mean / ro_t.mean),
        ]);
    }
    t.print();
    println!(
        "\nclaim check: reorder schedule imbalance ~1.0 at all thread counts (CSR block-row \
         partition degrades as threads grow). Wall-clock speedup requires real cores; on a \
         single-CPU host (this image) the imbalance metric carries the claim and times are equal."
    );
}
