//! The int8 path's **second oracle** (docs/ARCHITECTURE.md §Quantization).
//!
//! Quantizing conv weights to per-channel i8 is lossy, so int8 sessions
//! cannot satisfy the crate's bitwise-vs-f32 oracle. They satisfy two
//! weaker-but-checkable contracts instead, and this suite pins both:
//!
//! 1. **Error-bounded vs f32** — for every demo app × storage format
//!    {Dense, Csr, Compact} × batch {1, 4}, the int8 session's outputs
//!    stay inside the frozen per-app envelope
//!    ([`perfmodel::int8_error_bound`]): max-abs AND mean-abs difference
//!    against the f32 session on the same deterministic inputs.
//! 2. **Bitwise within int8** — i8×i8→i32 accumulation is exact integer
//!    arithmetic, so thread count (1 vs 4) and kernel ISA (native vs
//!    `force_scalar`) must not move a single bit of an int8 session's
//!    output. The lossy step is the *encode*, which happens once at plan
//!    time; everything downstream is deterministic.
//!
//! Plus the supporting claims: int8 conv weights are genuinely smaller
//! than their f32 encodings, and plans report int8 scratch.

use prt_dnn::apps::builders::{build_coloring, build_sr, build_style};
use prt_dnn::apps::{AppSpec, Variant};
use prt_dnn::perfmodel::int8_error_bound;
use prt_dnn::session::{Format, Model, Quantization};
use prt_dnn::tensor::Tensor;

/// Small-scale compiled model for one demo app (quick-test sizes).
fn test_model(app: &str) -> Model {
    let (base, spec) = match app {
        "style" => (build_style(32, 0.25, 601), AppSpec::for_app("style")),
        "coloring" => (build_coloring(32, 0.25, 602), AppSpec::for_app("coloring")),
        "sr" => (build_sr(24, 4, 0.25, 603), AppSpec::for_app("sr")),
        _ => unreachable!(),
    };
    Model::from_graph(&base, &spec, Variant::PrunedCompiler)
}

/// Deterministic input in the apps' natural activation range.
fn test_input(shape: &[usize], salt: usize) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = 0.5 + 0.45 * ((i as f32 * 0.37) + (salt as f32 * 2.1)).sin();
    }
    x
}

/// (max_abs, mean_abs) elementwise difference across all outputs.
fn output_error(a: &[Tensor], b: &[Tensor]) -> (f64, f64) {
    let mut max_abs = 0.0f64;
    let mut sum_abs = 0.0f64;
    let mut n = 0usize;
    for (ta, tb) in a.iter().zip(b) {
        assert_eq!(ta.shape(), tb.shape());
        for (&x, &y) in ta.data().iter().zip(tb.data()) {
            let d = (x as f64 - y as f64).abs();
            max_abs = max_abs.max(d);
            sum_abs += d;
            n += 1;
        }
    }
    (max_abs, sum_abs / n.max(1) as f64)
}

#[test]
fn int8_outputs_stay_inside_the_documented_envelope() {
    let formats =
        [("dense", Format::Dense), ("csr", Format::Csr), ("compact", Format::Compact)];
    for app in ["style", "coloring", "sr"] {
        let model = test_model(app);
        let bound = int8_error_bound(app);
        for &(tag, fmt) in &formats {
            for batch in [1usize, 4] {
                let f32s = model
                    .session()
                    .threads(1)
                    .batch(batch)
                    .sparse(fmt)
                    .build()
                    .unwrap();
                let q = model
                    .session()
                    .threads(1)
                    .batch(batch)
                    .sparse(fmt)
                    .quantize(Quantization::Int8)
                    .build()
                    .unwrap();
                assert!(q.plan().quantized(), "{}/{}/b{}", app, tag, batch);

                let inputs: Vec<Tensor> = f32s
                    .shapes()
                    .inputs
                    .iter()
                    .map(|s| test_input(s, batch))
                    .collect();
                let want = f32s.run(&inputs).unwrap();
                let got = q.run(&inputs).unwrap();
                let (max_abs, mean_abs) = output_error(&want, &got);
                assert!(
                    max_abs <= bound.max_abs,
                    "{}/{}/batch{}: max-abs {} > bound {}",
                    app,
                    tag,
                    batch,
                    max_abs,
                    bound.max_abs
                );
                assert!(
                    mean_abs <= bound.mean_abs,
                    "{}/{}/batch{}: mean-abs {} > bound {}",
                    app,
                    tag,
                    batch,
                    mean_abs,
                    bound.mean_abs
                );

                // Integer accumulation is exact: 4 threads, same bits.
                let q4 = model
                    .session()
                    .threads(4)
                    .batch(batch)
                    .sparse(fmt)
                    .quantize(Quantization::Int8)
                    .build()
                    .unwrap();
                let got4 = q4.run(&inputs).unwrap();
                for (a, b) in got.iter().zip(got4.iter()) {
                    assert_eq!(
                        a.data(),
                        b.data(),
                        "{}/{}/batch{}: int8 moved bits across thread counts",
                        app,
                        tag,
                        batch
                    );
                }
            }
        }
    }
}

#[test]
fn int8_is_bitwise_identical_across_isas() {
    // The SIMD i8 primitives must agree with the scalar ones *exactly* —
    // unlike f32, there is no relaxed flavor for integers.
    for app in ["style", "coloring", "sr"] {
        let model = test_model(app);
        let native = model
            .session()
            .threads(2)
            .quantize(Quantization::Int8)
            .build()
            .unwrap();
        let scalar = model
            .session()
            .threads(2)
            .quantize(Quantization::Int8)
            .force_scalar(true)
            .build()
            .unwrap();
        let inputs: Vec<Tensor> =
            native.shapes().inputs.iter().map(|s| test_input(s, 9)).collect();
        let a = native.run(&inputs).unwrap();
        let b = scalar.run(&inputs).unwrap();
        for (ta, tb) in a.iter().zip(b.iter()) {
            assert_eq!(
                ta.data(),
                tb.data(),
                "{}: {:?} int8 kernels disagree with scalar",
                app,
                native.isa()
            );
        }
    }
}

#[test]
fn int8_weights_and_scratch_are_accounted() {
    for app in ["style", "coloring", "sr"] {
        let model = test_model(app);
        for fmt in [Format::Dense, Format::Csr, Format::Compact] {
            let f = model.session().threads(1).sparse(fmt).build().unwrap();
            let q = model
                .session()
                .threads(1)
                .sparse(fmt)
                .quantize(Quantization::Int8)
                .build()
                .unwrap();
            // i8 values are 4x smaller; scales/indices keep it from a full
            // 4x, but conv-heavy models must come out well under f32.
            assert!(
                q.weight_bytes() < f.weight_bytes(),
                "{}/{:?}: int8 weights {} !< f32 {}",
                app,
                fmt,
                q.weight_bytes(),
                f.weight_bytes()
            );
            assert!(q.plan().quantized());
            assert!(q.plan().qpatch_len() > 0 && q.plan().qacc_len() > 0);
            assert!(!f.plan().quantized());
        }
    }
}

#[test]
fn int8_composes_with_fusion_and_no_fuse_agrees() {
    // The requantize epilogue feeds the same fused tail as f32; disabling
    // fusion must not change int8 bits (the epilogue math is identical,
    // only step grouping differs — and int8's integer core is exact).
    let model = test_model("style");
    let fused = model.session().threads(1).quantize(Quantization::Int8).build().unwrap();
    let unfused = model
        .session()
        .threads(1)
        .quantize(Quantization::Int8)
        .fuse(false)
        .build()
        .unwrap();
    assert!(fused.fused_steps() > 0, "style should fuse at least one chain");
    assert_eq!(unfused.fused_steps(), 0);
    let inputs: Vec<Tensor> =
        fused.shapes().inputs.iter().map(|s| test_input(s, 3)).collect();
    let a = fused.run(&inputs).unwrap();
    let b = unfused.run(&inputs).unwrap();
    for (ta, tb) in a.iter().zip(b.iter()) {
        assert_eq!(ta.data(), tb.data(), "fusion moved int8 bits");
    }
}
