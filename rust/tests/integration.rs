//! Cross-module integration tests: apps × variants × compiler pipeline,
//! graph file round trips, serving, sparsity accounting.

use prt_dnn::apps::{build_app, prune_graph, AppSpec, Variant};
use prt_dnn::dsl::io;
use prt_dnn::perfmodel::{estimate_graph, Device, VariantKind};
use prt_dnn::pruning::{graph_sparsity_report, verify::verify_structure};
use prt_dnn::session::{Model, ServeOpts, Session};
use prt_dnn::tensor::Tensor;

fn input_for(session: &Session) -> Tensor {
    Tensor::full(&session.shapes().inputs[0], 0.5)
}

#[test]
fn all_apps_all_variants_agree() {
    // The three pruned variants share weights; outputs must agree to float
    // tolerance across completely different kernel implementations.
    for app in ["style", "coloring", "sr"] {
        let mut reference: Option<Tensor> = None;
        for variant in [Variant::Pruned, Variant::PrunedFusedOnly, Variant::PrunedCompiler] {
            let session = Model::for_app_scaled(app, variant, 0.25, 42)
                .unwrap()
                .session()
                .threads(2)
                .build()
                .unwrap();
            let out = session.run(&[input_for(&session)]).unwrap().remove(0);
            match &reference {
                None => reference = Some(out),
                Some(r) => {
                    let err = r.max_abs_diff(&out);
                    assert!(err < 2e-3, "{} {:?}: err={}", app, variant.name(), err);
                }
            }
        }
    }
}

#[test]
fn pruned_weights_satisfy_declared_structure() {
    for app in ["style", "coloring", "sr", "vgg16"] {
        let mut g = build_app(app, 0.25, 1).unwrap();
        let spec = AppSpec::for_app(app);
        let schemes = prune_graph(&mut g, &spec);
        assert!(!schemes.is_empty(), "{}: nothing pruned", app);
        for (name, s) in &schemes {
            let w = g.param(&format!("{}.weight", name)).unwrap();
            verify_structure(w, s).unwrap_or_else(|e| panic!("{}/{}: {}", app, name, e));
        }
        let report = graph_sparsity_report(&g, &schemes).unwrap();
        let pruned_layers = report.iter().filter(|l| l.sparsity() > 0.3).count();
        assert!(pruned_layers >= schemes.len(), "{}: sparsity not reflected", app);
    }
}

#[test]
fn graph_file_roundtrip_preserves_semantics() {
    let dir = std::env::temp_dir().join("prt_dnn_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let g = build_app("sr", 0.25, 5).unwrap();
    let path = dir.join(format!("{}.graph.json", g.name));
    io::save(&g, &path).unwrap();
    let g2 = io::load(&path).unwrap();

    let s1 = Model::from_compiled(g, Vec::new()).session().threads(1).build().unwrap();
    let s2 = Model::from_compiled(g2, Vec::new()).session().threads(1).build().unwrap();
    let x = input_for(&s1);
    let o1 = s1.run(std::slice::from_ref(&x)).unwrap();
    let o2 = s2.run(std::slice::from_ref(&x)).unwrap();
    assert_eq!(o1[0].data(), o2[0].data(), "roundtrip changed outputs");
}

#[test]
fn serving_all_apps_realtime_judgement_runs() {
    for app in ["style", "coloring"] {
        let session = Model::for_app_scaled(app, Variant::PrunedCompiler, 0.25, 9)
            .unwrap()
            .session()
            .threads(2)
            .build()
            .unwrap();
        let shape = session.shapes().inputs[0].clone();
        let report = session
            .serve(
                &ServeOpts {
                    fps: 100.0,
                    queue_depth: 4,
                    workers: 1,
                    frames: 12,
                    ..ServeOpts::default()
                },
                |_| Tensor::full(&shape, 0.5),
            )
            .unwrap();
        assert!(report.processed >= 1, "{}: {}", app, report.render());
    }
}

#[test]
fn cost_model_orders_variants_for_every_app() {
    let device = Device::adreno640();
    for app in ["style", "coloring", "sr"] {
        let g = build_app(app, 1.0, 42).unwrap();
        let spec = AppSpec::for_app(app);
        let (dense, _) = estimate_graph(&g, &device, VariantKind::DenseUnfused, &[]).unwrap();
        let mut pruned = g.clone();
        let schemes = prune_graph(&mut pruned, &spec);
        let (csr, _) =
            estimate_graph(&pruned, &device, VariantKind::CsrUnfused, &schemes).unwrap();
        let mut fused = pruned.clone();
        prt_dnn::passes::PassManager::default().run_fixpoint(&mut fused, 4);
        let (compact, _) =
            estimate_graph(&fused, &device, VariantKind::CompactFused, &schemes).unwrap();
        assert!(csr < dense, "{}: pruning must help ({} vs {})", app, csr, dense);
        assert!(compact < csr, "{}: compiler must help ({} vs {})", app, compact, csr);
        let speedup = dense / compact;
        assert!(
            (2.0..8.0).contains(&speedup),
            "{}: total speedup {} outside the paper's band",
            app,
            speedup
        );
    }
}

#[test]
fn fusion_reduces_modeled_data_movement() {
    let device = Device::adreno640();
    let g = build_app("coloring", 1.0, 3).unwrap();
    let (_, unfused) = estimate_graph(&g, &device, VariantKind::DenseUnfused, &[]).unwrap();
    let (_, fused) = estimate_graph(&g, &device, VariantKind::DenseFused, &[]).unwrap();
    let bytes_unfused: f64 = unfused.iter().map(|c| c.bytes).sum();
    let bytes_fused: f64 = fused.iter().map(|c| c.bytes).sum();
    assert!(
        bytes_fused < bytes_unfused * 0.9,
        "fusion should cut >10% of traffic: {} vs {}",
        bytes_fused,
        bytes_unfused
    );
}
