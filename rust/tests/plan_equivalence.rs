//! Plan/arena correctness: the memory-planned executor (arena reuse +
//! in-place claims) must be **bit-identical** to a no-reuse plan — one
//! private range per value, no aliasing — which is semantically the
//! historical one-Tensor-per-node interpreter. Covers all three app graphs
//! under SparseMode::{Dense, Csr, Compact}.

use prt_dnn::apps::builders::{build_coloring, build_sr, build_style};
use prt_dnn::apps::{prune_graph, AppSpec};
use prt_dnn::dsl::Graph;
use prt_dnn::executor::{
    Engine, ExecConfig, ExecContext, PlanOptions, Planner, SparseMode,
};
use prt_dnn::tensor::Tensor;

fn structured_input(shape: &[usize]) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = 0.5 + 0.4 * ((i as f32) * 0.23).sin();
    }
    x
}

/// Reuse-plan vs no-reuse-plan bitwise equivalence for one (graph, config).
fn assert_planned_equivalence(tag: &str, g: &Graph, cfg: &ExecConfig) {
    let plan = Planner::plan(g, cfg).unwrap();
    let oracle = Planner::plan_with(g, cfg, PlanOptions::no_reuse()).unwrap();
    plan.validate_layout().unwrap();
    oracle.validate_layout().unwrap();
    assert!(
        plan.arena_len() < oracle.arena_len(),
        "{}: reuse plan ({}) should beat one-slot-per-value ({})",
        tag,
        plan.arena_len(),
        oracle.arena_len()
    );
    assert!(plan.inplace_steps() > 0, "{}: no in-place steps claimed", tag);

    let x = structured_input(&plan.input_shapes()[0]);
    let mut ctx = ExecContext::for_plan(&plan);
    let got = ctx.run(&plan, std::slice::from_ref(&x)).unwrap();
    let mut octx = ExecContext::for_plan(&oracle);
    let want = octx.run(&oracle, std::slice::from_ref(&x)).unwrap();
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(want.iter()) {
        assert_eq!(a.shape(), b.shape(), "{}", tag);
        assert_eq!(a.data(), b.data(), "{}: planned != no-reuse oracle", tag);
    }

    // A second frame through the same context must be bit-identical too
    // (stale arena contents must never leak into results).
    let again = ctx.run(&plan, std::slice::from_ref(&x)).unwrap();
    assert_eq!(again[0].data(), got[0].data(), "{}: context reuse drifted", tag);

    // The Engine facade runs the same plan.
    let eng = Engine::with_config(g, cfg).unwrap();
    let via_engine = eng.run(std::slice::from_ref(&x)).unwrap();
    assert_eq!(via_engine[0].data(), got[0].data(), "{}: engine != context", tag);
}

fn check_app(app: &str, base: Graph) {
    let spec = AppSpec::for_app(app);
    let mut pruned = base.clone();
    let schemes = prune_graph(&mut pruned, &spec);
    assert!(!schemes.is_empty(), "{}: nothing pruned", app);

    assert_planned_equivalence(&format!("{}/dense", app), &base, &ExecConfig::dense(2));
    assert_planned_equivalence(
        &format!("{}/csr", app),
        &pruned,
        &ExecConfig {
            sparse: SparseMode::Csr,
            threads: 2,
            schemes: schemes.clone(),
            tune: prt_dnn::tuner::TuneOpts::off(),
            batch: 1,
            force_scalar: false,
            relaxed_simd: false,
            fuse: true,
        },
    );
    assert_planned_equivalence(
        &format!("{}/compact", app),
        &pruned,
        &ExecConfig::compact(2, schemes),
    );
}

#[test]
fn style_planned_equivalence_all_modes() {
    check_app("style", build_style(64, 0.25, 41));
}

#[test]
fn coloring_planned_equivalence_all_modes() {
    check_app("coloring", build_coloring(64, 0.25, 42));
}

#[test]
fn sr_planned_equivalence_all_modes() {
    check_app("sr", build_sr(24, 4, 0.25, 43));
}

#[test]
fn memory_usage_is_consistent_across_modes() {
    let base = build_style(64, 0.25, 44);
    let spec = AppSpec::for_app("style");
    let mut pruned = base.clone();
    let schemes = prune_graph(&mut pruned, &spec);
    let dense = Planner::plan(&base, &ExecConfig::dense(1)).unwrap();
    let compact = Planner::plan(&pruned, &ExecConfig::compact(1, schemes)).unwrap();
    // Compact weights shrink the dedicated footprint; arenas are identical
    // topology so the shared footprint stays in the same ballpark.
    assert!(compact.memory().dedicated_bytes < dense.memory().dedicated_bytes);
    assert_eq!(
        dense.memory().peak_bytes,
        dense.memory().dedicated_bytes + dense.memory().shared_bytes
    );
}
