//! SIMD microkernel equivalence contract (see `docs/ARCHITECTURE.md`
//! §Microkernels):
//!
//! * **Order-preserving mode (the default)** — a session compiled for the
//!   host's SIMD tier must be **bitwise identical** to the same session
//!   pinned to the scalar kernels via `force_scalar`. The SIMD kernels
//!   keep the scalar accumulation association order, so this is an exact
//!   `assert_eq!` on output bits, end to end through every app variant.
//! * **Relaxed mode** — `relaxed_simd(true)` opts into FMA kernels whose
//!   fused multiply-add skips the intermediate product rounding. Results
//!   then legitimately differ from scalar by a few ulps; this suite bounds
//!   that drift with a max-ulp check rather than pretending it is zero.
//!
//! Both halves run the full session front door, so they also pin the ISA
//! introspection surface: `Session::isa`, `ExecutionPlan::isa`, and the
//! per-step `isa` field in `schedules_json`.

use prt_dnn::apps::builders::{build_coloring, build_style};
use prt_dnn::apps::{AppSpec, Variant};
use prt_dnn::kernels::gemm::{gemm_ref, gemm_with};
use prt_dnn::kernels::micro::{self, Isa};
use prt_dnn::session::{Model, Session};
use prt_dnn::tensor::Tensor;
use prt_dnn::tuner::Schedule;
use prt_dnn::util::threadpool::ComputePool;

/// Maximum ulp drift tolerated per element in relaxed (FMA) mode. FMA
/// changes each accumulation step by well under one ulp of the product;
/// over a whole network the drift stays orders of magnitude below this
/// deliberately generous bound — the assertion is that relaxed mode is
/// *close*, while catching any real kernel bug (wrong element, dropped
/// tail) which lands thousands of times further away.
const MAX_ULPS: i64 = 1 << 16;
/// Absolute escape hatch for near-zero outputs, where ulp distance is
/// meaningless (denormal neighborhoods).
const ABS_EPS: f32 = 1e-4;

/// Monotonic integer key for ulp distance: adjacent finite f32 values map
/// to adjacent integers, with -0.0 and +0.0 both at 0.
fn ulp_key(x: f32) -> i64 {
    let i = x.to_bits() as i32 as i64;
    if i < 0 {
        (i32::MIN as i64) - i
    } else {
        i
    }
}

fn ulp_dist(a: f32, b: f32) -> i64 {
    (ulp_key(a) - ulp_key(b)).abs()
}

fn assert_close_ulps(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{}", tag);
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let d = ulp_dist(*g, *w);
        assert!(
            d <= MAX_ULPS || (g - w).abs() <= ABS_EPS,
            "{}: element {} drifted {} ulps ({} vs {})",
            tag,
            i,
            d,
            g,
            w
        );
    }
}

fn model_for(app: &str, variant: Variant) -> Model {
    let g = match app {
        "style" => build_style(32, 0.25, 71),
        "coloring" => build_coloring(32, 0.25, 72),
        other => panic!("unknown app {}", other),
    };
    Model::from_graph(&g, &AppSpec::for_app(app), variant)
}

fn structured_input(shape: &[usize]) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = 0.5 + 0.4 * ((i as f32) * 0.23).sin();
    }
    x
}

fn run_once(s: &Session) -> Vec<Tensor> {
    let x = structured_input(&s.shapes().inputs[0]);
    s.run(std::slice::from_ref(&x)).unwrap()
}

/// Order-preserving mode: SIMD sessions are bitwise identical to their
/// force-scalar twins for every app variant, at batch 1 and batched.
#[test]
fn simd_sessions_match_scalar_sessions_bitwise() {
    for app in ["style", "coloring"] {
        for variant in [Variant::Unpruned, Variant::Pruned, Variant::PrunedCompiler] {
            for batch in [1usize, 2] {
                let model = model_for(app, variant);
                let simd =
                    model.session().threads(2).batch(batch).build().unwrap();
                let scalar = model
                    .session()
                    .threads(2)
                    .batch(batch)
                    .force_scalar(true)
                    .build()
                    .unwrap();
                assert_eq!(scalar.isa(), Isa::Scalar);
                assert_eq!(simd.isa(), micro::detect());
                let got = run_once(&simd);
                let want = run_once(&scalar);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(want.iter()) {
                    assert_eq!(
                        a.data(),
                        b.data(),
                        "{}/{:?}/batch{}: SIMD != scalar bits",
                        app,
                        variant,
                        batch
                    );
                }
            }
        }
    }
}

/// Relaxed mode: the FMA flavor stays within the documented ulp bound of
/// the scalar result across full networks. On a scalar-only host (or
/// under `PALLAS_FORCE_SCALAR`) relaxed sanitizes away and the comparison
/// collapses to bitwise — the test still holds.
#[test]
fn relaxed_simd_sessions_stay_within_ulp_bound() {
    for app in ["style", "coloring"] {
        let model = model_for(app, Variant::PrunedCompiler);
        let relaxed =
            model.session().threads(2).relaxed_simd(true).build().unwrap();
        let scalar = model.session().threads(2).force_scalar(true).build().unwrap();
        let got = run_once(&relaxed);
        let want = run_once(&scalar);
        for (a, b) in got.iter().zip(want.iter()) {
            assert_close_ulps(a.data(), b.data(), &format!("{}/relaxed", app));
        }
    }
}

/// Kernel-level relaxed bound: FMA GEMM vs the reference triple loop on
/// shapes with unaligned tails in every dimension.
#[test]
fn relaxed_gemm_is_ulp_bounded_against_reference() {
    let det = micro::detect();
    if det == Isa::Scalar {
        return; // nothing to relax on this host
    }
    for &(m, k, n) in &[(7usize, 33usize, 19usize), (16, 64, 24), (5, 128, 9)] {
        let a: Vec<f32> =
            (0..m * k).map(|i| ((i as f32) * 0.37).sin() * 0.5).collect();
        let b: Vec<f32> =
            (0..k * n).map(|i| ((i as f32) * 0.21).cos() * 0.5).collect();
        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, k, n, &a, &b, &mut want);
        let sched =
            Schedule { isa: det, relaxed: true, mr: 4, nr: 16, ..Schedule::default() };
        for threads in [1usize, 4] {
            let pool = ComputePool::new(threads);
            let mut got = vec![0.0f32; m * n];
            gemm_with(m, k, n, &a, &b, &mut got, &pool, &sched);
            assert_close_ulps(
                &got,
                &want,
                &format!("gemm {}x{}x{} t{}", m, k, n, threads),
            );
        }
    }
}

/// The introspection surface reports the plan's ISA: every tuner-visible
/// step schedule carries the plan tag, and forcing scalar flips all of it.
#[test]
fn schedules_json_reports_the_plan_isa() {
    let model = model_for("style", Variant::PrunedCompiler);
    let simd = model.session().threads(1).build().unwrap();
    let forced = model.session().threads(1).force_scalar(true).build().unwrap();
    for (s, isa) in [(&simd, micro::detect()), (&forced, Isa::Scalar)] {
        assert_eq!(s.isa(), isa);
        assert_eq!(s.plan().isa(), isa);
        let j = s.schedules_json();
        let obj = j.as_obj().expect("schedules_json is an object");
        assert!(!obj.is_empty());
        for (name, sched) in obj.iter() {
            assert_eq!(
                sched.get("isa").as_str(),
                Some(isa.tag()),
                "step '{}' must carry the plan ISA",
                name
            );
            assert_eq!(
                sched.get("relaxed").as_bool(),
                Some(false),
                "step '{}': relaxed is never on by default",
                name
            );
        }
    }
}
