//! Acceptance suite for the `session` front door (the PR-5 API redesign):
//!
//! 1. **Old-vs-new bitwise equivalence** — for all three app graphs ×
//!    {dense, csr, compact} × batch {1, 4}, a `Session` built through
//!    `Model::from_graph(..).session().…().build()` produces **bitwise
//!    identical** outputs to (a) the pre-redesign recipe spelled out by
//!    hand (`prune_graph` → `PassManager` → `ExecConfig` →
//!    `Engine::with_config`) and (b) the deprecated `prepare_variant*`
//!    shims that used to be the public entry points.
//! 2. **Typed negative paths** — `SessionError::{UnknownApp,
//!    UnknownVariant, ZeroThreads, ZeroBatch}` are returned (and
//!    downcastable) instead of panics or stringly errors.
//! 3. **Introspection** — `shapes()` / `memory()` / `schedules_json()`
//!    agree with the underlying plan, and serving runs as a mode of the
//!    session (including the adaptive `max_wait` batching knob).

use prt_dnn::apps::builders::{build_coloring, build_sr, build_style};
use prt_dnn::apps::{prune_graph, AppSpec, Variant};
use prt_dnn::dsl::Graph;
use prt_dnn::executor::{Engine, ExecConfig, SparseMode};
use prt_dnn::passes::PassManager;
use prt_dnn::session::{Format, Model, ServeOpts, SessionError};
use prt_dnn::tensor::Tensor;
use prt_dnn::tuner::TuneOpts;

fn structured_input(shape: &[usize]) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = 0.5 + 0.4 * ((i as f32) * 0.23).sin();
    }
    x
}

fn app_graph(app: &str) -> Graph {
    match app {
        "style" => build_style(32, 0.25, 151),
        "coloring" => build_coloring(32, 0.25, 152),
        "sr" => build_sr(24, 4, 0.25, 153),
        _ => unreachable!(),
    }
}

/// The pre-redesign recipe, spelled out by hand exactly as
/// `prepare_variant_batched` used to implement it: clone, prune when the
/// variant prunes, run the pass pipeline when it compiles, pick the
/// storage mode, compile an `Engine`.
fn legacy_engine(
    base: &Graph,
    spec: &AppSpec,
    variant: Variant,
    threads: usize,
    batch: usize,
) -> Engine {
    let mut g = base.clone();
    let schemes = match variant {
        Variant::Pruned | Variant::PrunedCompiler | Variant::PrunedFusedOnly => {
            prune_graph(&mut g, spec)
        }
        _ => Vec::new(),
    };
    if matches!(
        variant,
        Variant::PrunedCompiler | Variant::PrunedFusedOnly | Variant::UnprunedCompiler
    ) {
        PassManager::default().run_fixpoint(&mut g, 4);
    }
    let sparse = match variant {
        Variant::Unpruned | Variant::UnprunedCompiler => SparseMode::Dense,
        Variant::Pruned | Variant::PrunedFusedOnly => SparseMode::Csr,
        Variant::PrunedCompiler => SparseMode::Compact,
    };
    let cfg = ExecConfig {
        sparse,
        threads,
        schemes,
        tune: TuneOpts::off(),
        batch,
        force_scalar: false,
        relaxed_simd: false,
        fuse: true,
    };
    Engine::with_config(&g, &cfg).unwrap()
}

/// Session-built plans are bitwise identical to both legacy paths for
/// 3 apps × {dense, csr, compact} × batch {1, 4}.
#[test]
fn session_matches_legacy_paths_bitwise() {
    let threads = 2;
    for app in ["style", "coloring", "sr"] {
        let base = app_graph(app);
        let spec = AppSpec::for_app(app);
        for (tag, variant, format) in [
            ("dense", Variant::Unpruned, Format::Dense),
            ("csr", Variant::Pruned, Format::Csr),
            ("compact", Variant::PrunedCompiler, Format::Compact),
        ] {
            let model = Model::from_graph(&base, &spec, variant);
            assert_eq!(model.default_format(), format, "{}/{}", app, tag);
            for batch in [1usize, 4] {
                let session = model
                    .session()
                    .threads(threads)
                    .batch(batch)
                    .build()
                    .unwrap_or_else(|e| panic!("{}/{}/b{}: {}", app, tag, batch, e));

                let x = structured_input(&session.shapes().inputs[0]);

                // (a) the hand-spelled pre-redesign recipe.
                let old = legacy_engine(&base, &spec, variant, threads, batch);
                let want = old.run(std::slice::from_ref(&x)).unwrap();

                // (b) the deprecated shim that used to be the entry point.
                #[allow(deprecated)]
                let (shim, _) = prt_dnn::apps::variant::prepare_variant_batched(
                    &base,
                    variant,
                    &spec,
                    threads,
                    batch,
                    &TuneOpts::off(),
                )
                .unwrap();
                let via_shim = shim.run(std::slice::from_ref(&x)).unwrap();

                let got = session.run(std::slice::from_ref(&x)).unwrap();
                assert_eq!(got.len(), want.len());
                for (k, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                    assert_eq!(a.shape(), b.shape(), "{}/{}/b{}", app, tag, batch);
                    assert_eq!(
                        a.data(),
                        b.data(),
                        "{}/{}/b{} output {}: session moved bits vs legacy recipe",
                        app,
                        tag,
                        batch,
                        k
                    );
                }
                for (a, b) in want.iter().zip(via_shim.iter()) {
                    assert_eq!(
                        a.data(),
                        b.data(),
                        "{}/{}/b{}: deprecated shim drifted from legacy recipe",
                        app,
                        tag,
                        batch
                    );
                }
            }
        }
    }
}

/// The option space fails with matchable typed errors, not panics.
#[test]
fn typed_negative_paths() {
    // Unknown app.
    let err = Model::for_app("no-such-app", Variant::Unpruned).unwrap_err();
    assert_eq!(
        err.downcast_ref::<SessionError>(),
        Some(&SessionError::UnknownApp("no-such-app".into()))
    );

    // Unknown variant name.
    assert_eq!(
        Variant::parse("warp-speed"),
        Err(SessionError::UnknownVariant("warp-speed".into()))
    );

    // Zero thread / batch budgets.
    let base = app_graph("style");
    let model = Model::from_graph(&base, &AppSpec::for_app("style"), Variant::Unpruned);
    let err = model.session().threads(0).build().unwrap_err();
    assert_eq!(err.downcast_ref::<SessionError>(), Some(&SessionError::ZeroThreads));
    let err = model.session().batch(0).build().unwrap_err();
    assert_eq!(err.downcast_ref::<SessionError>(), Some(&SessionError::ZeroBatch));
    // The messages are stable and mention the constraint.
    assert!(SessionError::ZeroBatch.to_string().contains("batch"));

    // Wrong input geometry still fails at run time (executor-level check).
    let session = model.session().threads(1).build().unwrap();
    assert!(session.run(&[Tensor::zeros(&[1, 3, 8, 8])]).is_err());
    assert!(session.run(&[]).is_err());
}

/// Introspection agrees with the plan, and per-frame geometry divides the
/// batch back out.
#[test]
fn introspection_is_consistent() {
    let base = app_graph("coloring");
    let model = Model::from_graph(&base, &AppSpec::for_app("coloring"), Variant::PrunedCompiler);
    let session = model.session().threads(1).batch(3).build().unwrap();
    assert_eq!(session.batch(), 3);
    assert_eq!(session.threads(), 1);
    assert_eq!(session.variant(), Some(Variant::PrunedCompiler));

    let shapes = session.shapes();
    assert_eq!(shapes.inputs, session.plan().input_shapes());
    assert_eq!(shapes.outputs, session.plan().output_shapes());
    assert_eq!(shapes.inputs[0][0], 3 * shapes.frame_inputs[0][0]);
    assert_eq!(shapes.outputs[0][0], 3 * shapes.frame_outputs[0][0]);

    let mem = session.memory();
    assert_eq!(mem.peak_bytes, mem.dedicated_bytes + mem.shared_bytes);
    assert_eq!(session.weight_bytes(), session.plan().weight_bytes);

    // Untuned plans still serialize their (default) per-step schedules.
    let sched = session.schedules_json();
    assert!(!sched.as_obj().unwrap().is_empty());

    // run_frames round-trips per-frame tensors through the batched plan.
    let frames: Vec<Vec<Tensor>> = (0..3)
        .map(|f| vec![structured_input(&shapes.frame_inputs[0]).map(|v| v + f as f32 * 0.01)])
        .collect();
    let refs: Vec<&[Tensor]> = frames.iter().map(|v| v.as_slice()).collect();
    let outs = session.run_frames(&refs).unwrap();
    assert_eq!(outs.len(), 3);
    assert_eq!(outs[0][0].shape(), shapes.frame_outputs[0].as_slice());
}

/// Serving is a mode of the session: batch comes from the plan, the
/// adaptive deadline is a serve knob, and the report carries it.
#[test]
fn serving_is_a_session_mode() {
    let base = app_graph("style");
    let model = Model::from_graph(&base, &AppSpec::for_app("style"), Variant::PrunedCompiler);
    let session = model.session().threads(2).batch(2).build().unwrap();
    let fshape = session.shapes().frame_inputs[0].clone();
    let report = session
        .serve(
            &ServeOpts {
                fps: 200.0,
                queue_depth: 8,
                workers: 1,
                frames: 16,
                max_wait: std::time::Duration::from_millis(500),
            },
            |_| Tensor::full(&fshape, 0.5),
        )
        .unwrap();
    assert_eq!(report.processed + report.dropped, 16);
    assert_eq!(report.batch, 2, "serve batch comes from the session's plan");
    assert!(report.frames_per_dispatch >= 1.0);
    assert_eq!(report.max_wait_ms, 500.0);
    let j = report.to_json();
    assert_eq!(j.get("batch").as_usize(), Some(2));
    assert_eq!(j.get("max_wait_ms").as_f64(), Some(500.0));
}

/// A tuned session is bitwise identical to the untuned one (the tuner
/// moves time, never bits) — the front-door mirror of
/// `tuner_equivalence.rs`.
#[test]
fn tuned_session_matches_untuned_bitwise() {
    let cache = std::env::temp_dir()
        .join(format!("prt-session-api-tune-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&cache);
    let base = app_graph("style");
    let model = Model::from_graph(&base, &AppSpec::for_app("style"), Variant::PrunedCompiler);
    let plain = model.session().threads(2).build().unwrap();
    let tuned = model
        .session()
        .threads(2)
        .tune(TuneOpts::quick(&cache))
        .build()
        .unwrap();
    assert!(!plain.plan().tuned() && tuned.plan().tuned());
    let x = structured_input(&plain.shapes().inputs[0]);
    let a = plain.run(std::slice::from_ref(&x)).unwrap();
    let b = tuned.run(std::slice::from_ref(&x)).unwrap();
    assert_eq!(a[0].data(), b[0].data(), "tuned session moved bits");
    let _ = std::fs::remove_file(&cache);
}
