//! Acceptance suite for the multi-model serving fleet (router → batching
//! → session → engine):
//!
//! 1. **Trace equivalence** — for all three app graphs × {Dense, Csr,
//!    Compact} storage, an interleaved request trace routed through a
//!    fleet (2 workers, batch-2 coalescing, 5 ms adaptive-batching
//!    deadline) returns outputs **bitwise identical** to a solo batch-1
//!    single-thread session on the same model. Routing, queueing,
//!    cross-request batching and padding must never move a bit — the
//!    fleet extends the batch-equivalence oracle, not replaces it.
//! 2. **Typed negative paths** — unknown model id, bad input shapes,
//!    duplicate registration, empty fleet and queue-full overload all
//!    surface as matchable [`FleetError`]s, not panics.
//! 3. **Admission control** — a `workers == 0` fleet admits exactly
//!    `queue_depth` requests, rejects the next with
//!    [`FleetError::Overloaded`], and [`Fleet::pump`] drains the queue in
//!    deterministic batched dispatches whose outputs still match solo.
//! 4. **Weight dedup** — replicas over one `Arc<Session>` and separately
//!    built sessions over one [`Model`] both hold a single copy of the
//!    dense weights ([`Session::memory`] is the oracle).
//! 5. **Seeded load generation** — a closed-loop run over a 2:1 tenant
//!    mix emits a fleet report whose counters reconcile and whose JSON
//!    carries the full latency surface (p50/p99/p999 + histogram).

use prt_dnn::apps::builders::{build_coloring, build_sr, build_style};
use prt_dnn::apps::{AppSpec, Variant};
use prt_dnn::fleet::{FleetBuilder, FleetError, LoadGen, WeightStore};
use prt_dnn::session::{Format, Model};
use prt_dnn::tensor::Tensor;
use std::sync::Arc;
use std::time::Duration;

/// Deterministic, per-frame-distinct input (the batch_equivalence
/// formula): frame `f` of shape `shape`.
fn frame_input(shape: &[usize], f: usize) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = 0.5 + 0.4 * ((i as f32 * 0.23) + (f as f32 * 1.7)).sin();
    }
    x
}

/// Small-scale compiled model for one demo app (the quick-test builder
/// sizes, not benchmark scale).
fn test_model(app: &str) -> Model {
    let (base, spec) = match app {
        "style" => (build_style(32, 0.25, 301), AppSpec::for_app("style")),
        "coloring" => (build_coloring(32, 0.25, 302), AppSpec::for_app("coloring")),
        "sr" => (build_sr(24, 4, 0.25, 303), AppSpec::for_app("sr")),
        _ => unreachable!(),
    };
    Model::from_graph(&base, &spec, Variant::PrunedCompiler)
}

#[test]
fn fleet_trace_matches_solo_sessions() {
    const FRAMES: usize = 8;
    let formats = [
        ("dense", Format::Dense),
        ("csr", Format::Csr),
        ("compact", Format::Compact),
    ];
    for app in ["style", "coloring", "sr"] {
        let model = test_model(app);

        // Solo oracles: batch 1, single thread, one per storage format.
        let solo: Vec<_> = formats
            .iter()
            .map(|&(_, fmt)| {
                model.session().threads(1).batch(1).sparse(fmt).build().unwrap()
            })
            .collect();

        // The fleet under test: same model behind three hosts (one per
        // format), each with background workers and batch-2 coalescing.
        let mut builder = FleetBuilder::new()
            .queue_depth(32)
            .max_wait(Duration::from_millis(5))
            .workers(2);
        for &(tag, fmt) in &formats {
            builder = builder
                .register(tag, model.session().threads(2).batch(2).sparse(fmt))
                .unwrap();
        }
        let fleet = builder.build().unwrap();

        // Interleaved trace: frame f goes to every host before frame f+1
        // is offered anywhere, so dispatches coalesce across requests.
        let mut tickets = Vec::new();
        for f in 0..FRAMES {
            for &(tag, _) in &formats {
                let shapes = fleet.session(tag).unwrap().shapes();
                let inputs: Vec<Tensor> =
                    shapes.frame_inputs.iter().map(|s| frame_input(s, f)).collect();
                tickets.push((tag, f, fleet.submit(tag, inputs).unwrap()));
            }
        }
        for (tag, f, ticket) in tickets {
            let got = ticket.wait().unwrap();
            let pos = formats.iter().position(|&(t, _)| t == tag).unwrap();
            let shapes = solo[pos].shapes();
            let inputs: Vec<Tensor> =
                shapes.frame_inputs.iter().map(|s| frame_input(s, f)).collect();
            let want = solo[pos].run(&inputs).unwrap();
            assert_eq!(want.len(), got.len(), "{}/{} frame {}", app, tag, f);
            for (k, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert_eq!(a.shape(), b.shape(), "{}/{} f={} out={}", app, tag, f, k);
                assert_eq!(
                    a.data(),
                    b.data(),
                    "{}/{} frame={} output={}: fleet routing moved bits",
                    app,
                    tag,
                    f,
                    k
                );
            }
        }

        let report = fleet.shutdown();
        assert_eq!(report.completed, FRAMES * formats.len(), "{}", app);
        assert_eq!(report.rejected, 0, "{}", app);
        assert_eq!(report.failed, 0, "{}", app);
        for m in &report.models {
            assert_eq!(m.submitted, FRAMES, "{}/{}", app, m.id);
            assert_eq!(m.completed, FRAMES, "{}/{}", app, m.id);
            // Coalescing can't exceed the compiled batch.
            assert!(
                m.frames_per_dispatch >= 1.0 && m.frames_per_dispatch <= 2.0,
                "{}/{}: frames/dispatch {}",
                app,
                m.id,
                m.frames_per_dispatch
            );
            assert_eq!(m.hist.total(), FRAMES as u64, "{}/{}", app, m.id);
        }
    }
}

#[test]
fn unknown_model_and_builder_errors_are_typed() {
    let model = test_model("style");
    let fleet = FleetBuilder::new()
        .workers(0)
        .register("style", model.session().threads(1).batch(1))
        .unwrap()
        .build()
        .unwrap();

    // Unknown model id.
    let err = fleet.submit("nope", vec![]).unwrap_err();
    assert_eq!(
        err.downcast_ref::<FleetError>(),
        Some(&FleetError::UnknownModel("nope".into()))
    );

    // Wrong input arity.
    let err = fleet.submit("style", vec![]).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<FleetError>(),
        Some(FleetError::BadInput { model, .. }) if model == "style"
    ));

    // Wrong input shape.
    let err = fleet.submit("style", vec![Tensor::zeros(&[1, 2, 3])]).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<FleetError>(),
        Some(FleetError::BadInput { .. })
    ));

    // Duplicate registration.
    let err = FleetBuilder::new()
        .register("m", model.session().threads(1).batch(1))
        .unwrap()
        .register("m", model.session().threads(1).batch(1))
        .unwrap_err();
    assert_eq!(
        err.downcast_ref::<FleetError>(),
        Some(&FleetError::DuplicateModel("m".into()))
    );

    // Empty fleet.
    let err = FleetBuilder::new().build().unwrap_err();
    assert_eq!(err.downcast_ref::<FleetError>(), Some(&FleetError::EmptyFleet));

    // Error messages are stable and name the model.
    assert!(FleetError::UnknownModel("x".into()).to_string().contains('x'));
    assert!(FleetError::Overloaded { model: "x".into(), depth: 4 }
        .to_string()
        .contains("x"));
}

#[test]
fn admission_control_rejects_then_drains_correctly() {
    let model = test_model("style");
    let solo = model.session().threads(1).batch(1).build().unwrap();
    // workers == 0: nothing dispatches until `pump`, so queue occupancy is
    // fully deterministic.
    let fleet = FleetBuilder::new()
        .queue_depth(3)
        .workers(0)
        .register("style", model.session().threads(1).batch(2))
        .unwrap()
        .build()
        .unwrap();
    let shapes = fleet.session("style").unwrap().shapes();
    let mk = |f: usize| -> Vec<Tensor> {
        shapes.frame_inputs.iter().map(|s| frame_input(s, f)).collect()
    };

    // Exactly queue_depth admissions, then typed backpressure.
    let tickets: Vec<_> =
        (0..3).map(|f| fleet.submit("style", mk(f)).unwrap()).collect();
    assert_eq!(fleet.queue_len("style").unwrap(), 3);
    let err = fleet.submit("style", mk(3)).unwrap_err();
    assert_eq!(
        err.downcast_ref::<FleetError>(),
        Some(&FleetError::Overloaded { model: "style".into(), depth: 3 })
    );

    // Deterministic drain: batch-2 dispatch, then a padded 1-frame
    // dispatch, then nothing.
    assert_eq!(fleet.pump("style").unwrap(), 2);
    assert_eq!(fleet.pump("style").unwrap(), 1);
    assert_eq!(fleet.pump("style").unwrap(), 0);

    // Routed + batched + padded outputs still match solo bitwise.
    for (f, ticket) in tickets.into_iter().enumerate() {
        let got = ticket.wait().unwrap();
        let want = solo.run(&mk(f)).unwrap();
        for (a, b) in want.iter().zip(got.iter()) {
            assert_eq!(a.data(), b.data(), "frame {}: pump dispatch moved bits", f);
        }
    }

    let report = fleet.shutdown();
    assert_eq!(report.submitted, 3);
    assert_eq!(report.rejected, 1);
    assert_eq!(report.completed, 3);
    assert_eq!(report.failed, 0);
    let m = &report.models[0];
    assert_eq!(m.dispatches, 2);
    assert_eq!(m.queue_peak, 3);
    assert_eq!(m.queue_depth, 3);
    assert!((m.frames_per_dispatch - 1.5).abs() < 1e-9);
}

#[test]
fn replicas_share_one_weight_copy() {
    // Dense baseline: every weight byte is a dense buffer, so the dedup
    // accounting is exact against Session::memory().
    let base = build_style(32, 0.25, 304);
    let spec = AppSpec::for_app("style");
    let model = Model::from_graph(&base, &spec, Variant::Unpruned);

    // Two replicas over ONE shared session: trivially one weight copy,
    // but two per-worker context allotments.
    let session = Arc::new(model.session().threads(1).batch(1).build().unwrap());
    let mem = session.memory();
    let fleet = FleetBuilder::new()
        .workers(0)
        .register_shared("a", Arc::clone(&session))
        .unwrap()
        .register_shared("b", Arc::clone(&session))
        .unwrap()
        .build()
        .unwrap();
    let report = fleet.shutdown();
    assert_eq!(report.unique_weight_bytes, mem.dedicated_bytes);
    assert_eq!(report.peak_bytes, mem.dedicated_bytes + 2 * mem.shared_bytes);
    // The naive per-model sum double-counts; the fleet figure doesn't.
    let naive: usize = report.models.iter().map(|m| m.weight_bytes).sum();
    assert_eq!(naive, 2 * report.unique_weight_bytes);

    // Two *separately built* sessions over one Model: distinct plans, but
    // copy-on-write weight tensors share the graph's buffers, so the
    // fleet still holds a single copy of the dense weights.
    let fleet = FleetBuilder::new()
        .workers(0)
        .register("a", model.session().threads(1).batch(1))
        .unwrap()
        .register("b", model.session().threads(2).batch(2))
        .unwrap()
        .build()
        .unwrap();
    let report = fleet.shutdown();
    assert_eq!(
        report.unique_weight_bytes, mem.dedicated_bytes,
        "independent sessions of one model must dedupe to one weight copy"
    );
}

#[test]
fn seeded_loadgen_emits_full_report() {
    // The store interns by key: same key, same Arc<Model>.
    let store = WeightStore::new();
    let style = store.get_or_build("style|test", || Ok(test_model("style"))).unwrap();
    let coloring =
        store.get_or_build("coloring|test", || Ok(test_model("coloring"))).unwrap();
    let again = store.get_or_build("style|test", || Ok(test_model("style"))).unwrap();
    assert!(Arc::ptr_eq(&style, &again), "store must intern by key");
    assert_eq!(store.len(), 2);

    let fleet = FleetBuilder::new()
        .queue_depth(64)
        .max_wait(Duration::from_millis(1))
        .workers(1)
        .register("style", style.session().threads(1).batch(2))
        .unwrap()
        .register("coloring", coloring.session().threads(1).batch(2))
        .unwrap()
        .build()
        .unwrap();

    const REQUESTS: usize = 24;
    let gen = LoadGen::closed(3, REQUESTS, 7)
        .mix(vec![("style".to_string(), 2.0), ("coloring".to_string(), 1.0)]);
    let stats = gen.run(&fleet).unwrap();
    assert_eq!(stats.offered, REQUESTS);
    assert_eq!(stats.accepted + stats.rejected, REQUESTS);
    // Closed loop with concurrency 3 << queue_depth 64 never overloads.
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.failed, 0);

    let report = fleet.shutdown();
    assert_eq!(report.submitted, stats.accepted);
    assert_eq!(report.completed, stats.accepted);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.failed, 0);

    // The latency surface is fully populated and ordered.
    let l = report.latency.as_ref().expect("completed requests imply a summary");
    assert_eq!(l.n, REQUESTS);
    assert!(l.p50 <= l.p99 && l.p99 <= l.p999 && l.p999 <= l.max);
    for m in &report.models {
        assert_eq!(m.submitted, m.completed, "{}", m.id);
        assert!(m.dispatches >= 1, "{}", m.id);
        assert!(
            m.frames_per_dispatch >= 1.0 && m.frames_per_dispatch <= m.batch as f64,
            "{}: frames/dispatch {}",
            m.id,
            m.frames_per_dispatch
        );
        assert_eq!(m.hist.total(), m.completed as u64, "{}", m.id);
    }

    // Machine-readable form carries the schema BENCH_SCHEMA.md documents.
    let j = report.to_json();
    assert_eq!(j.get("submitted").as_usize(), Some(REQUESTS));
    assert!(j.get("latency_p999_ms").as_f64().is_some());
    assert!(j.get("unique_weight_bytes").as_usize().is_some());
    let models = j.get("models").as_arr().unwrap();
    assert_eq!(models.len(), 2);
    for mj in models {
        assert!(mj.get("rejected").as_usize().is_some());
        assert!(mj.get("dispatches").as_usize().is_some());
        assert!(mj.get("latency_p999_ms").as_f64().is_some());
        let hist = mj.get("hist");
        let le = hist.get("le_ms").as_arr().unwrap();
        assert_eq!(le.len(), hist.get("count").as_arr().unwrap().len());
    }
}

#[test]
fn zero_request_fleet_reports_instead_of_panicking() {
    use prt_dnn::util::json::Json;

    // Regression: summarising an empty sample set used to assert inside
    // `Summary::from_samples`, so a fleet shut down before any request —
    // or with a tenant the mix never routed to — panicked instead of
    // reporting. Both must now degrade to `-` / `null`.
    let style = test_model("style");
    let coloring = test_model("coloring");
    let fleet = FleetBuilder::new()
        .workers(1)
        .register("style", style.session().threads(1).batch(1))
        .unwrap()
        .register("coloring", coloring.session().threads(1).batch(1))
        .unwrap()
        .build()
        .unwrap();

    // Route one request to style only; coloring finishes with zero.
    let shapes = fleet.session("style").unwrap().shapes();
    let inputs: Vec<Tensor> =
        shapes.frame_inputs.iter().map(|s| frame_input(s, 0)).collect();
    fleet.submit("style", inputs).unwrap().wait().unwrap();
    let report = fleet.shutdown();
    assert_eq!(report.completed, 1);
    let quiet = report.models.iter().find(|m| m.id == "coloring").unwrap();
    assert_eq!(quiet.completed, 0);
    assert!(quiet.latency.is_none());
    let r = report.render();
    assert!(r.contains("| ms p50=- p99=- p999=-"), "{}", r);
    let j = report.to_json();
    let mj = j
        .get("models")
        .as_arr()
        .unwrap()
        .iter()
        .find(|m| m.get("model").as_str() == Some("coloring"))
        .unwrap();
    assert!(matches!(mj.get("latency_p50_ms"), Json::Null));
    assert!(matches!(mj.get("infer_mean_ms"), Json::Null));

    // A fleet torn down before ANY request still reports (fleet-wide `-`).
    let idle = FleetBuilder::new()
        .workers(0)
        .register("style", test_model("style").session().threads(1).batch(1))
        .unwrap()
        .build()
        .unwrap();
    let report = idle.shutdown();
    assert_eq!(report.completed, 0);
    let r = report.render();
    assert!(r.contains("latency ms p50=- p90=- p99=- p999=- max=-"), "{}", r);
    assert!(matches!(report.to_json().get("latency_p999_ms"), Json::Null));
}
