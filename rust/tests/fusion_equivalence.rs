//! Differential fuzz suite pinning plan-time operator fusion
//! (`executor::fusion` + the planner's compound-step emission): for a
//! population of seeded random small DAGs built through the DSL, a fused
//! plan must be **bitwise identical** to the same graph planned with
//! `--no-fuse`, across thread counts {1, 4} × batch {1, 3} × storage
//! formats {Dense, Csr, Compact}. The fused epilogue replays the exact
//! per-element expressions of the absorbed steps, so there is no tolerance
//! anywhere — `assert_eq!` on the raw `f32` bits.
//!
//! Every case is generated from a deterministic seed via the shared
//! `check_prop` harness, which reports the failing seed on panic so any
//! counterexample replays exactly. The generator grows append-only DAGs of
//! conv / depthwise-conv / standalone activation / residual-add nodes
//! (shape-preserving, 8×8 spatial, ≤ 8 channels — small enough that the
//! whole population runs in seconds) plus a dense-layer MLP flavor, so
//! chains land on all three kernel tiers. Sparse coverage prunes the same
//! graph with the style app's column spec and replans under
//! `SparseMode::{Csr, Compact}`.

use prt_dnn::apps::{prune_graph, AppSpec};
use prt_dnn::dsl::{Activation, Graph, Op, PadMode};
use prt_dnn::executor::{ExecConfig, ExecContext, Planner};
use prt_dnn::tensor::Tensor;
use prt_dnn::util::rng::{check_prop, Rng};

/// Seeded population size (the issue floor is 50).
const CASES: u64 = 60;

const ACTS: [Activation; 4] = [
    Activation::Relu,
    Activation::LeakyRelu,
    Activation::Tanh,
    Activation::Sigmoid,
];

/// Random shape-preserving conv DAG: every value is `[1, c, 8, 8]`, so any
/// pair of values can feed a residual add and any value can grow a chain.
fn random_conv_graph(rng: &mut Rng) -> Graph {
    let c = [4usize, 6, 8][rng.below(3)];
    let mut g = Graph::new("fuzz-conv");
    let x = g.add("x", Op::Input { shape: vec![1, c, 8, 8] }, &[]);
    let mut vals = vec![x];
    let mut convs = 0usize;
    let layers = rng.range(4, 9);
    for i in 0..layers {
        // Last layer is forced to be a conv if none was emitted yet, so
        // every graph has at least one fusion producer.
        let kind = if i + 1 == layers && convs == 0 { 0 } else { rng.below(8) };
        let from = vals[rng.below(vals.len())];
        let id = match kind {
            // conv (weighted: the main chain producer).
            0..=2 => {
                let name = format!("c{}", i);
                let id = g.add(
                    &name,
                    Op::Conv2d {
                        out_c: c,
                        in_c: c,
                        kh: 3,
                        kw: 3,
                        stride: 1,
                        pad: 1,
                        pad_mode: PadMode::Zeros,
                        fused_act: ACTS[rng.below(4)],
                    },
                    &[from],
                );
                g.set_param(format!("{}.weight", name), Tensor::randn(&[c, c, 3, 3], rng));
                if rng.below(2) == 0 {
                    g.set_param(format!("{}.bias", name), Tensor::randn(&[c], rng));
                }
                convs += 1;
                id
            }
            3 => {
                let name = format!("dw{}", i);
                let id = g.add(
                    &name,
                    Op::DepthwiseConv2d {
                        c,
                        kh: 3,
                        kw: 3,
                        stride: 1,
                        pad: 1,
                        fused_act: ACTS[rng.below(4)],
                    },
                    &[from],
                );
                g.set_param(format!("{}.weight", name), Tensor::randn(&[c, 1, 3, 3], rng));
                id
            }
            4..=5 => g.add(format!("a{}", i), Op::Act(ACTS[rng.below(4)]), &[from]),
            _ => {
                let other = vals[rng.below(vals.len())];
                g.add(format!("s{}", i), Op::Add, &[from, other])
            }
        };
        vals.push(id);
    }
    let last = *vals.last().unwrap();
    g.add("out", Op::Output, &[last]);
    g
}

/// Random MLP so chains also land on the dense kernel tier.
fn random_mlp_graph(rng: &mut Rng) -> Graph {
    let f = 16usize;
    let mut g = Graph::new("fuzz-mlp");
    let x = g.add("x", Op::Input { shape: vec![1, f] }, &[]);
    let mut vals = vec![x];
    for i in 0..rng.range(3, 7) {
        let from = vals[rng.below(vals.len())];
        let id = match rng.below(4) {
            0..=1 => {
                let name = format!("d{}", i);
                let id = g.add(
                    &name,
                    Op::Dense { out_f: f, in_f: f, fused_act: ACTS[rng.below(4)] },
                    &[from],
                );
                g.set_param(format!("{}.weight", name), Tensor::randn(&[f, f], rng));
                id
            }
            2 => g.add(format!("a{}", i), Op::Act(ACTS[rng.below(4)]), &[from]),
            _ => {
                let other = vals[rng.below(vals.len())];
                g.add(format!("s{}", i), Op::Add, &[from, other])
            }
        };
        vals.push(id);
    }
    let last = *vals.last().unwrap();
    g.add("out", Op::Output, &[last]);
    g
}

/// Structured, sign-varying input (activation kinks on both sides of 0).
fn fuzz_input(shape: &[usize]) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = ((i as f32) * 0.37).sin() * 0.9;
    }
    x
}

/// Fused plan vs `--no-fuse` plan for one (graph, config): bitwise equal
/// outputs, and the fused arena never larger. Returns the fused step count
/// so the driver can assert the population actually exercises fusion.
fn assert_fused_equivalence(tag: &str, g: &Graph, cfg: &ExecConfig) -> usize {
    let fused = Planner::plan(g, cfg).unwrap_or_else(|e| panic!("{}: fused plan: {}", tag, e));
    let unfused = Planner::plan(g, &cfg.clone().with_fuse(false))
        .unwrap_or_else(|e| panic!("{}: unfused plan: {}", tag, e));
    fused.validate_layout().unwrap();
    unfused.validate_layout().unwrap();
    // Both plans must pass the static verifier (arena / race / schedule /
    // fusion invariants) before any bitwise comparison: a verifier hit
    // here localizes a planner bug that the output diff would only show
    // as unexplained corruption.
    let fv = prt_dnn::verify::verify_plan(&fused);
    assert!(fv.is_empty(), "{}: fused plan failed static verification: {:?}", tag, fv);
    let uv = prt_dnn::verify::verify_plan(&unfused);
    assert!(uv.is_empty(), "{}: unfused plan failed static verification: {:?}", tag, uv);
    assert_eq!(unfused.fused_steps(), 0, "{}", tag);
    assert!(
        fused.arena_len() <= unfused.arena_len(),
        "{}: fusion grew the arena ({} > {})",
        tag,
        fused.arena_len(),
        unfused.arena_len()
    );

    let x = fuzz_input(&fused.input_shapes()[0]);
    let mut fctx = ExecContext::for_plan(&fused);
    let got = fctx.run(&fused, std::slice::from_ref(&x)).unwrap();
    let mut uctx = ExecContext::for_plan(&unfused);
    let want = uctx.run(&unfused, std::slice::from_ref(&x)).unwrap();
    assert_eq!(got.len(), want.len(), "{}", tag);
    for (k, (a, b)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(a.shape(), b.shape(), "{} output {}", tag, k);
        assert_eq!(
            a.data(),
            b.data(),
            "{} output {}: fused plan moved bits vs --no-fuse",
            tag,
            k
        );
    }
    // Second frame through the warm fused context: the compound epilogue
    // must not depend on cold arena contents.
    let again = fctx.run(&fused, std::slice::from_ref(&x)).unwrap();
    assert_eq!(again[0].data(), got[0].data(), "{}: fused context reuse drifted", tag);
    fused.fused_steps()
}

/// All {Dense, Csr, Compact} × threads {1, 4} × batch {1, 3} cells for one
/// random graph.
fn check_graph(tag: &str, g: &Graph, fused_total: &mut usize) {
    g.validate().unwrap_or_else(|e| panic!("{}: invalid graph: {}", tag, e));
    // Sparse coverage reuses the column-pruning spec (the style app's);
    // graphs whose convs are all exempt simply run the sparse modes with
    // dense fallbacks, which is still a fusion path worth pinning.
    let mut pruned = g.clone();
    let schemes = prune_graph(&mut pruned, &AppSpec::for_app("style"));
    for threads in [1usize, 4] {
        for batch in [1usize, 3] {
            let dense = ExecConfig::dense(threads).with_batch(batch);
            *fused_total += assert_fused_equivalence(
                &format!("{}/dense/t{}/b{}", tag, threads, batch),
                g,
                &dense,
            );
            let mut csr = ExecConfig::csr(threads).with_batch(batch);
            csr.schemes = schemes.clone();
            *fused_total += assert_fused_equivalence(
                &format!("{}/csr/t{}/b{}", tag, threads, batch),
                &pruned,
                &csr,
            );
            let compact = ExecConfig::compact(threads, schemes.clone()).with_batch(batch);
            *fused_total += assert_fused_equivalence(
                &format!("{}/compact/t{}/b{}", tag, threads, batch),
                &pruned,
                &compact,
            );
        }
    }
}

#[test]
fn random_graphs_fused_matches_unfused_bitwise() {
    let mut fused_total = 0usize;
    let mut case = 0u64;
    check_prop("fusion-differential", CASES, |rng| {
        case += 1;
        // Every 4th seed is an MLP so the dense tier stays covered.
        let g = if case % 4 == 0 { random_mlp_graph(rng) } else { random_conv_graph(rng) };
        let tag = format!("case{}", case);
        check_graph(&tag, &g, &mut fused_total);
    });
    // The suite is vacuous if the generator stops producing fusable
    // chains — demand a healthy number of compound steps across the run.
    assert!(
        fused_total >= CASES as usize,
        "population under-exercises fusion: {} compound steps across {} cases",
        fused_total,
        CASES
    );

    // One rotating seed on top of the pinned population: CI exports
    // FUZZ_EXTRA_SEED (its run id), so coverage widens run-over-run while
    // the base population stays reproducible. The seed is printed so any
    // counterexample replays exactly with the same env var locally.
    if let Ok(s) = std::env::var("FUZZ_EXTRA_SEED") {
        let seed: u64 = s.parse().expect("FUZZ_EXTRA_SEED must be a u64");
        println!("fusion-differential: rotating extra seed {}", seed);
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B9));
        let g = if seed % 4 == 0 {
            random_mlp_graph(&mut rng)
        } else {
            random_conv_graph(&mut rng)
        };
        check_graph(&format!("extra-seed{}", seed), &g, &mut fused_total);
    }
}

/// One hand-written worst case pinned outside the random population: a
/// producer whose full act→add→act tail absorbs, with the residual as the
/// *first* Add operand (the operand-order hazard for `-0.0` / NaN bit
/// patterns) and a second consumer keeping the residual alive.
#[test]
fn residual_first_chain_is_bitwise_stable() {
    let mut rng = Rng::new(0xF05E);
    let mut g = Graph::new("resfirst");
    let x = g.add("x", Op::Input { shape: vec![1, 4, 8, 8] }, &[]);
    let c = g.add(
        "c",
        Op::Conv2d {
            out_c: 4,
            in_c: 4,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            pad_mode: PadMode::Zeros,
            fused_act: Activation::Identity,
        },
        &[x],
    );
    g.set_param("c.weight", Tensor::randn(&[4, 4, 3, 3], &mut rng));
    g.set_param("c.bias", Tensor::randn(&[4], &mut rng));
    let a = g.add("a", Op::Act(Activation::LeakyRelu), &[c]);
    let s = g.add("s", Op::Add, &[x, a]); // residual first
    let p = g.add("p", Op::Act(Activation::Tanh), &[s]);
    g.add("out", Op::Output, &[p]);

    let mut fused_total = 0usize;
    check_graph("resfirst", &g, &mut fused_total);
    assert!(fused_total > 0, "the hand-written chain must fuse somewhere");
}
