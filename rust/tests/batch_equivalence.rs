//! Acceptance suite for batched execution (plan → kernels → engine):
//!
//! 1. **Batch equivalence** — for all three app graphs × batch ∈
//!    {1, 2, 3, 4} × threads ∈ {1, 4}, a batched run is **bitwise
//!    identical** to N sequential single-frame runs on the same inputs,
//!    across the dense, CSR and compact (column / pattern) storage
//!    variants, plus the `Reordered` fallback (filter scheme). The pool
//!    may partition work across the combined `N × rows` space, but every
//!    output element keeps its single-frame fp expression, so batching
//!    must never move a bit.
//! 2. **Typed negative paths** — `Planner::plan_with` rejects `batch == 0`
//!    and the batched entry points reject a wrong frame count / per-frame
//!    input count with matchable [`PlanError`]s, not panics.
//! 3. **Plan geometry** — batched `input_shapes` / `output_shapes` scale
//!    dim 0 by N and `frame_*_shapes` divide it back out.

use prt_dnn::apps::builders::{build_coloring, build_sr, build_style};
use prt_dnn::apps::{prune_graph, AppSpec};
use prt_dnn::dsl::Graph;
use prt_dnn::executor::{ExecConfig, ExecContext, PlanError, Planner};
use prt_dnn::pruning::scheme::project_scheme;
use prt_dnn::pruning::verify::apply_mask;
use prt_dnn::tensor::Tensor;

/// Deterministic, per-frame-distinct input: frame `f` of shape `shape`.
fn frame_input(shape: &[usize], f: usize) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = 0.5 + 0.4 * ((i as f32 * 0.23) + (f as f32 * 1.7)).sin();
    }
    x
}

/// The storage variants of one app: (tag, graph, config builder input).
fn app_variants(app: &str) -> Vec<(String, Graph, ExecConfig)> {
    let (base, spec) = match app {
        "style" => (build_style(32, 0.25, 201), AppSpec::for_app("style")),
        "coloring" => (build_coloring(32, 0.25, 202), AppSpec::for_app("coloring")),
        "sr" => (build_sr(24, 4, 0.25, 203), AppSpec::for_app("sr")),
        _ => unreachable!(),
    };
    let mut pruned = base.clone();
    let schemes = prune_graph(&mut pruned, &spec);
    assert!(!schemes.is_empty(), "{}: nothing pruned", app);
    let mut out = vec![
        (format!("{}/dense", app), base.clone(), ExecConfig::dense(1)),
        (format!("{}/csr", app), pruned.clone(), ExecConfig::csr(1)),
        (
            format!("{}/compact", app),
            pruned,
            ExecConfig::compact(1, schemes),
        ),
    ];
    if app == "style" {
        // The `Reordered` fallback: a filter scheme has no declared
        // column/pattern structure, so the planner compiles the
        // filter-signature reorder kernel (per-group gather panels).
        let mut g = base;
        let name = "res0_c1";
        let w = g.param(&format!("{}.weight", name)).unwrap().clone();
        let s = project_scheme(&w, "filter", 0.5, None);
        g.set_param(format!("{}.weight", name), apply_mask(&w, &s));
        out.push((
            "style/reordered-fallback".to_string(),
            g,
            ExecConfig::compact(1, vec![(name.to_string(), s)]),
        ));
    }
    out
}

#[test]
fn batched_runs_match_sequential_bitwise() {
    for &threads in &[1usize, 4] {
        for app in ["style", "coloring", "sr"] {
            for (tag, g, cfg) in app_variants(app) {
                let mut cfg = cfg;
                cfg.threads = threads;

                // Reference: single-frame plan + context.
                let p1 = Planner::plan(&g, &cfg.clone().with_batch(1)).unwrap();
                let mut c1 = ExecContext::for_plan(&p1);
                let frame_shapes = p1.input_shapes();

                for batch in [1usize, 2, 3, 4] {
                    let pb = Planner::plan(&g, &cfg.clone().with_batch(batch)).unwrap();
                    pb.validate_layout().unwrap();
                    assert_eq!(pb.batch(), batch, "{}", tag);
                    assert_eq!(pb.frame_input_shapes(), frame_shapes, "{}", tag);

                    let frames: Vec<Vec<Tensor>> = (0..batch)
                        .map(|f| frame_shapes.iter().map(|s| frame_input(s, f)).collect())
                        .collect();
                    let frame_refs: Vec<&[Tensor]> =
                        frames.iter().map(|v| v.as_slice()).collect();

                    let mut cb = ExecContext::for_plan(&pb);
                    let got = cb.run_batch(&pb, &frame_refs).unwrap();
                    assert_eq!(got.len(), batch, "{}", tag);

                    for (f, frame) in frames.iter().enumerate() {
                        let want = c1.run(&p1, frame).unwrap();
                        assert_eq!(want.len(), got[f].len(), "{}", tag);
                        for (k, (a, b)) in want.iter().zip(got[f].iter()).enumerate() {
                            assert_eq!(a.shape(), b.shape(), "{} b={} f={}", tag, batch, f);
                            assert_eq!(
                                a.data(),
                                b.data(),
                                "{} t={} b={} frame={} output={}: batching moved bits",
                                tag,
                                threads,
                                batch,
                                f,
                                k
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn zero_batch_is_rejected_with_typed_error() {
    let g = build_style(32, 0.25, 210);
    let err = Planner::plan_with(
        &g,
        &ExecConfig::dense(1).with_batch(0),
        prt_dnn::executor::PlanOptions::default(),
    )
    .unwrap_err();
    assert_eq!(err.downcast_ref::<PlanError>(), Some(&PlanError::ZeroBatch));
    // The error message is stable and mentions the constraint.
    assert!(format!("{:#}", err).contains("batch"));
}

#[test]
fn mismatched_frame_inputs_are_rejected_with_typed_errors() {
    let g = build_style(32, 0.25, 211);
    let plan = Planner::plan(&g, &ExecConfig::dense(1).with_batch(2)).unwrap();
    let x = Tensor::full(&plan.frame_input_shapes()[0], 0.5);

    // Wrong frame count: 1 frame for a batch-2 plan.
    let one: Vec<&[Tensor]> = vec![std::slice::from_ref(&x)];
    let err = plan.pack_frames(&one).unwrap_err();
    assert_eq!(
        err.downcast_ref::<PlanError>(),
        Some(&PlanError::FrameCount { expected: 2, got: 1 })
    );

    // Wrong per-frame input count: frame 1 supplies no tensors.
    let empty: &[Tensor] = &[];
    let frames: Vec<&[Tensor]> = vec![std::slice::from_ref(&x), empty];
    let err = plan.pack_frames(&frames).unwrap_err();
    assert_eq!(
        err.downcast_ref::<PlanError>(),
        Some(&PlanError::FrameInputCount { frame: 1, expected: 1, got: 0 })
    );

    // The context-level convenience surfaces the same typed error.
    let mut ctx = ExecContext::for_plan(&plan);
    let err = ctx.run_batch(&plan, &one).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<PlanError>(),
        Some(PlanError::FrameCount { .. })
    ));
}

#[test]
fn engine_run_frames_round_trips() {
    use prt_dnn::executor::Engine;
    let g = build_style(32, 0.25, 212);
    let eng = Engine::with_config(&g, &ExecConfig::dense(2).with_batch(3)).unwrap();
    assert_eq!(eng.batch(), 3);
    let fshape = eng.plan().frame_input_shapes()[0].clone();
    assert_eq!(eng.input_shapes()[0][0], 3 * fshape[0]);

    let frames: Vec<Vec<Tensor>> = (0..3).map(|f| vec![frame_input(&fshape, f)]).collect();
    let frame_refs: Vec<&[Tensor]> = frames.iter().map(|v| v.as_slice()).collect();
    let outs = eng.run_frames(&frame_refs).unwrap();
    assert_eq!(outs.len(), 3);

    // Each frame agrees with a single-frame engine on the same graph.
    let single = Engine::with_config(&g, &ExecConfig::dense(2)).unwrap();
    for (f, frame) in frames.iter().enumerate() {
        let want = single.run(frame).unwrap();
        assert_eq!(want[0].data(), outs[f][0].data(), "frame {}", f);
    }
}
