//! Acceptance suite for the static plan verifier (`prt_dnn::verify`):
//!
//! 1. **Clean sweep** — every knob combination the runtime can emit
//!    (3 apps × {dense, csr, compact} × batch {1, 4} × threads {1, 4} ×
//!    {f32, int8} × {fused, unfused}) plans to zero violations. This is
//!    the soundness half: the analyzer must not cry wolf on any plan the
//!    planner actually produces.
//! 2. **Mutation detection** — `PlanMutator` corrupts a valid plan one
//!    invariant at a time (arena overlap, lane-boundary skew, foreign
//!    ISA, scratch shrink, fused-placeholder read, illegal in-place
//!    claim, slot shrink) and the verifier must flag each with the
//!    matching typed `Violation`. This is the completeness half: passing
//!    clean plans means nothing unless broken plans actually fail.

use prt_dnn::apps::builders::build_style;
use prt_dnn::apps::Variant;
use prt_dnn::dsl::op::{Activation, Op, PadMode};
use prt_dnn::dsl::Graph;
use prt_dnn::executor::{ExecConfig, ExecutionPlan, Planner};
use prt_dnn::pruning::scheme::project_scheme;
use prt_dnn::pruning::verify::apply_mask;
use prt_dnn::session::{Model, Quantization};
use prt_dnn::tensor::Tensor;
use prt_dnn::util::rng::Rng;
use prt_dnn::verify::{verify_plan, PlanMutator};

/// A small style-transfer plan (convs, residual adds, upsampling) — the
/// richest step mix of the three apps. Verified clean before returning,
/// so every mutation test starts from a provably good baseline.
fn style_plan(cfg: &ExecConfig) -> ExecutionPlan {
    let g = build_style(32, 0.25, 301);
    let p = Planner::plan(&g, cfg).unwrap();
    assert!(verify_plan(&p).is_empty(), "baseline style plan must verify clean");
    p
}

/// A one-conv graph filter-pruned by hand: filter/channel schemes are what
/// compile to the `Reordered` kernel (the stock apps use column/pattern),
/// and only that kernel has per-lane work-item boundaries to skew.
fn reordered_plan(threads: usize) -> ExecutionPlan {
    let mut rng = Rng::new(90);
    let mut g = Graph::new("reord-net");
    let x = g.add("x", Op::Input { shape: vec![1, 6, 12, 12] }, &[]);
    let c1 = g.add(
        "c1",
        Op::Conv2d {
            out_c: 16,
            in_c: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            pad_mode: PadMode::Zeros,
            fused_act: Activation::Relu,
        },
        &[x],
    );
    g.add("out", Op::Output, &[c1]);
    let w = Tensor::randn(&[16, 6, 3, 3], &mut rng);
    let scheme = project_scheme(&w, "filter", 0.5, None);
    g.set_param("c1.weight", apply_mask(&w, &scheme));
    let cfg = ExecConfig::compact(threads, vec![("c1".to_string(), scheme)]);
    let p = Planner::plan(&g, &cfg).unwrap();
    assert!(verify_plan(&p).is_empty(), "baseline reordered plan must verify clean");
    p
}

/// The corrupted plan must produce at least one violation carrying one of
/// the expected codes (a mutation may legitimately trip secondary checks
/// too — e.g. a shrunk slot is both a size mismatch and a write overflow).
fn assert_detects(plan: &ExecutionPlan, codes: &[&str], what: &str) {
    let found = verify_plan(plan);
    assert!(!found.is_empty(), "{}: verifier missed the corruption entirely", what);
    assert!(
        codes.iter().any(|c| found.iter().any(|v| v.code() == *c)),
        "{}: expected one of {:?}, got {:?}",
        what,
        codes,
        found
    );
    // Every violation renders a non-empty human-readable message.
    for v in &found {
        assert!(!v.to_string().is_empty(), "{}: empty Display for {:?}", what, v);
    }
}

#[test]
fn clean_sweep_every_knob_combination_verifies_zero_violations() {
    for app in ["style", "coloring", "sr"] {
        for variant in [Variant::Unpruned, Variant::Pruned, Variant::PrunedCompiler] {
            let model = Model::for_app_scaled(app, variant, 0.25, 42).unwrap();
            for batch in [1usize, 4] {
                for threads in [1usize, 4] {
                    for quant in [Quantization::None, Quantization::Int8] {
                        for fuse in [true, false] {
                            let session = model
                                .session()
                                .threads(threads)
                                .batch(batch)
                                .fuse(fuse)
                                .quantize(quant)
                                .build()
                                .unwrap();
                            let v = session.verify();
                            assert!(
                                v.is_empty(),
                                "{}[{}] batch={} threads={} {:?} fuse={}: {:?}",
                                app,
                                variant.name(),
                                batch,
                                threads,
                                quant,
                                fuse,
                                v
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn detects_arena_overlap() {
    let mut p = style_plan(&ExecConfig::dense(2));
    assert!(PlanMutator::new(&mut p).overlap_live_ranges(), "no mutation site");
    assert_detects(&p, &["arena-overlap"], "overlap_live_ranges");
}

#[test]
fn detects_skewed_lane_boundary_as_write_overlap() {
    // threads = 4 so the reordered step actually has multiple lanes with
    // per-lane row boundaries to skew.
    let mut p = reordered_plan(4);
    assert!(PlanMutator::new(&mut p).skew_lane_boundary(), "no reordered step to skew");
    assert_detects(&p, &["write-overlap"], "skew_lane_boundary t=4");

    // Single-lane plans fall back to duplicating a work item — the same
    // rows claimed twice is still a write overlap.
    let mut p1 = reordered_plan(1);
    assert!(PlanMutator::new(&mut p1).skew_lane_boundary(), "no reordered step to skew");
    assert_detects(&p1, &["write-overlap"], "skew_lane_boundary t=1");
}

#[test]
fn detects_foreign_isa() {
    let mut p = style_plan(&ExecConfig::dense(1));
    assert!(PlanMutator::new(&mut p).swap_step_isa(), "no kernel step to retarget");
    assert_detects(&p, &["isa-unavailable"], "swap_step_isa");
}

#[test]
fn detects_undersized_scratch() {
    let mut p = style_plan(&ExecConfig::dense(2));
    assert!(PlanMutator::new(&mut p).shrink_scratch(), "plan has no scratch to shrink");
    assert_detects(&p, &["scratch-undersized"], "shrink_scratch");
}

#[test]
fn detects_read_of_fused_placeholder() {
    let p0 = style_plan(&ExecConfig::dense(1));
    assert!(p0.fused_steps() > 0, "style plan must fuse for this test");
    let mut p = p0;
    assert!(PlanMutator::new(&mut p).read_fused_placeholder(), "no placeholder to rewire");
    assert_detects(&p, &["fused-read"], "read_fused_placeholder");
}

#[test]
fn detects_illegal_inplace_claim() {
    // --no-fuse keeps the residual adds as standalone steps, so some
    // value is read after the first step that consumes it — the liveness
    // conflict the mutation needs.
    let mut p = style_plan(&ExecConfig::dense(1).with_fuse(false));
    assert!(PlanMutator::new(&mut p).claim_illegal_inplace(), "no in-place site");
    assert_detects(&p, &["inplace-liveness"], "claim_illegal_inplace");
}

#[test]
fn detects_shrunken_output_slot() {
    let mut p = style_plan(&ExecConfig::dense(2));
    assert!(PlanMutator::new(&mut p).shrink_slot(), "no kernel slot to shrink");
    assert_detects(&p, &["slot-size", "write-oob"], "shrink_slot");
}

#[test]
fn violations_carry_stable_codes_and_anchor_ids() {
    let mut p = style_plan(&ExecConfig::dense(2));
    assert!(PlanMutator::new(&mut p).shrink_slot());
    let found = verify_plan(&p);
    assert!(!found.is_empty());
    for v in &found {
        assert!(!v.code().is_empty(), "{:?}: empty code", v);
        assert!(v.id() < p.len(), "{:?}: anchor id outside the plan's steps", v);
    }
}
