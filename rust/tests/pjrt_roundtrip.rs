//! PJRT integration: load the AOT artifacts produced by `make artifacts`,
//! execute them on the CPU PJRT client, and cross-check against the native
//! executor running the exported LR graph with the SAME weights.
//!
//! These tests are skipped (with a message) when artifacts/ is absent so
//! `cargo test` works before the python step; `make test` runs both.

use prt_dnn::dsl::io;
use prt_dnn::executor::Engine;
use prt_dnn::runtime::{Manifest, PjrtModel};
use prt_dnn::tensor::Tensor;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    // Tests run from the crate root.
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir.to_path_buf())
    } else {
        eprintln!("skipping PJRT test: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn artifacts_load_and_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    assert!(!manifest.entries.is_empty());
    let client = PjrtModel::cpu_client().unwrap();
    for entry in &manifest.entries {
        let model = PjrtModel::load(&client, entry)
            .unwrap_or_else(|e| panic!("{}: {:#}", entry.name, e));
        let inputs: Vec<Tensor> = entry
            .input_shapes
            .iter()
            .map(|s| Tensor::full(s, 0.5))
            .collect();
        let out = model.run(&inputs).unwrap();
        assert_eq!(out.len(), entry.output_shapes.len(), "{}", entry.name);
        for (o, expect) in out.iter().zip(entry.output_shapes.iter()) {
            assert_eq!(o.shape(), expect.as_slice(), "{}", entry.name);
            assert!(
                o.data().iter().all(|v| v.is_finite()),
                "{}: non-finite outputs",
                entry.name
            );
        }
    }
}

#[test]
fn native_executor_matches_pjrt_on_same_weights() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = PjrtModel::cpu_client().unwrap();
    for app in ["style_transfer", "coloring", "super_resolution"] {
        let Some(entry) = manifest.find(app, "dense") else { continue };
        let graph_path = dir.join(format!("{}.graph.json", app));
        if !graph_path.exists() {
            continue;
        }
        let g = io::load(&graph_path).unwrap();
        let eng = Engine::new(&g, 2).unwrap();
        let model = PjrtModel::load(&client, entry).unwrap();

        // Structured, non-constant input.
        let shape = entry.input_shapes[0].clone();
        let mut x = Tensor::zeros(&shape);
        for (i, v) in x.data_mut().iter_mut().enumerate() {
            *v = 0.5 + 0.4 * ((i as f32) * 0.37).sin();
        }
        let native = eng.run(std::slice::from_ref(&x)).unwrap();
        let pjrt = model.run(std::slice::from_ref(&x)).unwrap();
        assert_eq!(native[0].shape(), pjrt[0].shape(), "{}", app);
        let err = native[0].rel_l2(&pjrt[0]);
        assert!(
            err < 1e-3,
            "{}: native executor vs XLA rel-L2 {} (kernels disagree with jax)",
            app,
            err
        );
        println!("{}: native vs PJRT rel-L2 = {:.3e}", app, err);
    }
}

#[test]
fn pruned_artifacts_execute_and_differ_from_dense() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = PjrtModel::cpu_client().unwrap();
    for app in ["style_transfer", "super_resolution"] {
        let (Some(dense), Some(pruned)) =
            (manifest.find(app, "dense"), manifest.find(app, "pruned"))
        else {
            continue;
        };
        let dm = PjrtModel::load(&client, dense).unwrap();
        let pm = PjrtModel::load(&client, pruned).unwrap();
        // Structured input: a constant image is nulled by instance norm
        // (mean removal), which would make all weight changes invisible.
        let mut x = Tensor::zeros(&dense.input_shapes[0]);
        for (i, v) in x.data_mut().iter_mut().enumerate() {
            *v = 0.5 + 0.4 * ((i as f32) * 0.11).cos();
        }
        let od = dm.run(std::slice::from_ref(&x)).unwrap();
        let op = pm.run(std::slice::from_ref(&x)).unwrap();
        let diff = od[0].max_abs_diff(&op[0]);
        assert!(diff > 0.0, "{}: pruning left outputs identical", app);
    }
}
