//! Acceptance checks for the auto-tuning subsystem (`crate::tuner`):
//!
//! 1. **Bitwise equivalence** — a plan compiled with tuning enabled
//!    produces bit-identical outputs to the untuned plan on all three app
//!    graphs at `threads = 1` and `threads = 4`. Schedules are a pure
//!    performance knob; they must never move a bit.
//! 2. **Cache determinism** — `TuneCache` round-trips through its JSON
//!    form deterministically (sorted keys, byte-identical re-serialization).
//! 3. **Warm-cache planning** — the CI smoke configuration: a tiny
//!    width-0.25 graph tuned with a 2-candidate space populates the cache
//!    on the first plan; the second plan answers every key from the cache
//!    and performs **zero** micro-benchmark runs.

use prt_dnn::apps::builders::{build_coloring, build_sr, build_style};
use prt_dnn::apps::{prune_graph, AppSpec};
use prt_dnn::dsl::Graph;
use prt_dnn::executor::{ExecConfig, ExecContext, Planner};
use prt_dnn::tensor::Tensor;
use prt_dnn::tuner::{Schedule, TuneCache, TuneOpts};
use prt_dnn::util::json::Json;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("prt-tuner-eq-{}-{}.json", std::process::id(), name))
}

/// Every plan this suite compares must first pass the static verifier
/// (arena / race / schedule / fusion invariants): a tuned schedule that
/// races or overflows would otherwise only surface as an unexplained
/// bitwise diff downstream.
fn assert_verified(tag: &str, plan: &prt_dnn::executor::ExecutionPlan) {
    let v = prt_dnn::verify::verify_plan(plan);
    assert!(v.is_empty(), "{}: static verification failed: {:?}", tag, v);
}

fn structured_input(shape: &[usize]) -> Tensor {
    let mut x = Tensor::zeros(shape);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = 0.5 + 0.4 * ((i as f32) * 0.23).sin();
    }
    x
}

fn app_graph(app: &str) -> Graph {
    match app {
        "style" => build_style(32, 0.25, 61),
        "coloring" => build_coloring(32, 0.25, 62),
        "sr" => build_sr(24, 4, 0.25, 63),
        _ => unreachable!(),
    }
}

/// Tuned and default plans must agree bit-for-bit (per app, per thread
/// count, under the compact compiler configuration that exercises dense
/// stems + column/pattern kernels).
#[test]
fn tuned_plans_match_default_bitwise_on_all_apps() {
    for &threads in &[1usize, 4] {
        for app in ["style", "coloring", "sr"] {
            let mut g = app_graph(app);
            let schemes = prune_graph(&mut g, &AppSpec::for_app(app));
            assert!(!schemes.is_empty(), "{}: nothing pruned", app);

            let base_cfg = ExecConfig::compact(threads, schemes.clone());
            let cache = tmp(&format!("eq-{}-t{}", app, threads));
            let _ = std::fs::remove_file(&cache);
            let tuned_cfg =
                ExecConfig::compact(threads, schemes).with_tuning(TuneOpts::quick(&cache));

            let p0 = Planner::plan(&g, &base_cfg).unwrap();
            let p1 = Planner::plan(&g, &tuned_cfg).unwrap();
            assert_verified(&format!("{} t={} base", app, threads), &p0);
            assert_verified(&format!("{} t={} tuned", app, threads), &p1);
            assert!(!p0.tuned() && p1.tuned());

            let x = structured_input(&p0.input_shapes()[0]);
            let o0 = ExecContext::for_plan(&p0)
                .run(&p0, std::slice::from_ref(&x))
                .unwrap();
            let o1 = ExecContext::for_plan(&p1)
                .run(&p1, std::slice::from_ref(&x))
                .unwrap();
            assert_eq!(o0.len(), o1.len());
            for (a, b) in o0.iter().zip(o1.iter()) {
                assert_eq!(
                    a.data(),
                    b.data(),
                    "{} t={}: tuned schedules moved bits",
                    app,
                    threads
                );
            }
            let _ = std::fs::remove_file(&cache);
        }
    }
}

/// Fully-connected (`Op::Dense`) steps get TuneRequests too (the ROADMAP
/// gap): the tuner searches the schedule space for the FC layer — the
/// kernel honors the split knob — and the tuned plan stays bitwise
/// identical to the default. The plan-side schedule serialization lists
/// the dense step, proving a request was issued for it.
#[test]
fn dense_steps_are_tuned_and_match_default_bitwise() {
    use prt_dnn::dsl::op::{Activation, Op, PadMode};
    use prt_dnn::util::rng::Rng;

    let mut rng = Rng::new(88);
    let mut g = Graph::new("fc-net");
    let x = g.add("x", Op::Input { shape: vec![1, 4, 8, 8] }, &[]);
    let c1 = g.add(
        "c1",
        Op::Conv2d {
            out_c: 8,
            in_c: 4,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            pad_mode: PadMode::Zeros,
            fused_act: Activation::Relu,
        },
        &[x],
    );
    g.set_param("c1.weight", Tensor::randn(&[8, 4, 3, 3], &mut rng));
    let gap = g.add("gap", Op::GlobalAvgPool, &[c1]);
    let fc = g.add(
        "fc",
        Op::Dense { out_f: 10, in_f: 8, fused_act: Activation::Identity },
        &[gap],
    );
    g.set_param("fc.weight", Tensor::randn(&[10, 8], &mut rng));
    g.set_param("fc.bias", Tensor::randn(&[10], &mut rng));
    g.add("out", Op::Output, &[fc]);

    for &threads in &[1usize, 4] {
        let base_cfg = ExecConfig::dense(threads);
        let cache = tmp(&format!("fc-t{}", threads));
        let _ = std::fs::remove_file(&cache);
        let tuned_cfg = ExecConfig::dense(threads).with_tuning(TuneOpts::quick(&cache));

        let p0 = Planner::plan(&g, &base_cfg).unwrap();
        let p1 = Planner::plan(&g, &tuned_cfg).unwrap();
        assert_verified(&format!("fc t={} base", threads), &p0);
        assert_verified(&format!("fc t={} tuned", threads), &p1);
        assert!(p1.tuned());
        // A TuneRequest was issued for the dense step: its schedule shows
        // up in the plan-side serialization, and the search missed the
        // cold cache at least twice (conv + dense).
        let sched = p1.schedules_json();
        assert!(
            sched.get("fc").as_obj().is_some(),
            "t={}: no schedule recorded for the dense step: {}",
            threads,
            sched
        );
        assert!(p1.tune_stats().cache_misses >= 2, "t={}: conv + fc must both tune", threads);

        let x = structured_input(&p0.input_shapes()[0]);
        let o0 = ExecContext::for_plan(&p0).run(&p0, std::slice::from_ref(&x)).unwrap();
        let o1 = ExecContext::for_plan(&p1).run(&p1, std::slice::from_ref(&x)).unwrap();
        assert_eq!(o0[0].data(), o1[0].data(), "t={}: tuned FC schedule moved bits", threads);
        let _ = std::fs::remove_file(&cache);
    }
}

/// Depthwise (`Op::DepthwiseConv2d`) steps get TuneRequests too (the
/// ROADMAP gap): the tuner searches the dw split knob — plane-chunk vs
/// row-chunk pool partitioning — and the tuned plan stays bitwise
/// identical to the default. The plan-side schedule serialization lists
/// the depthwise step, proving a request was issued for it.
#[test]
fn depthwise_steps_are_tuned_and_match_default_bitwise() {
    use prt_dnn::dsl::op::{Activation, Op, PadMode};
    use prt_dnn::util::rng::Rng;

    let mut rng = Rng::new(89);
    let mut g = Graph::new("dw-net");
    let x = g.add("x", Op::Input { shape: vec![1, 6, 16, 16] }, &[]);
    let c1 = g.add(
        "c1",
        Op::Conv2d {
            out_c: 6,
            in_c: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            pad_mode: PadMode::Zeros,
            fused_act: Activation::Relu,
        },
        &[x],
    );
    g.set_param("c1.weight", Tensor::randn(&[6, 6, 3, 3], &mut rng));
    let dw = g.add(
        "dw",
        Op::DepthwiseConv2d {
            c: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            fused_act: Activation::Relu,
        },
        &[c1],
    );
    g.set_param("dw.weight", Tensor::randn(&[6, 1, 3, 3], &mut rng));
    g.set_param("dw.bias", Tensor::randn(&[6], &mut rng).map(|v| v * 0.1));
    g.add("out", Op::Output, &[dw]);

    for &threads in &[1usize, 4] {
        let base_cfg = ExecConfig::dense(threads);
        let cache = tmp(&format!("dw-t{}", threads));
        let _ = std::fs::remove_file(&cache);
        let tuned_cfg = ExecConfig::dense(threads).with_tuning(TuneOpts::quick(&cache));

        let p0 = Planner::plan(&g, &base_cfg).unwrap();
        let p1 = Planner::plan(&g, &tuned_cfg).unwrap();
        assert_verified(&format!("dw t={} base", threads), &p0);
        assert_verified(&format!("dw t={} tuned", threads), &p1);
        assert!(p1.tuned());
        // A TuneRequest was issued for the depthwise step: its schedule
        // shows up in the plan-side serialization, and the cold cache
        // missed at least twice (conv + dw).
        let sched = p1.schedules_json();
        assert!(
            sched.get("dw").as_obj().is_some(),
            "t={}: no schedule recorded for the depthwise step: {}",
            threads,
            sched
        );
        assert!(
            p1.tune_stats().cache_misses >= 2,
            "t={}: conv + dw must both tune",
            threads
        );

        let x = structured_input(&p0.input_shapes()[0]);
        let o0 = ExecContext::for_plan(&p0).run(&p0, std::slice::from_ref(&x)).unwrap();
        let o1 = ExecContext::for_plan(&p1).run(&p1, std::slice::from_ref(&x)).unwrap();
        assert_eq!(
            o0[0].data(),
            o1[0].data(),
            "t={}: tuned depthwise schedule moved bits",
            threads
        );
        let _ = std::fs::remove_file(&cache);
    }
}

/// The reordered kernel's work-item iteration order is part of the tuner's
/// candidate space (`Schedule::group_order`): tuning a filter-pruned graph
/// — whose compact execution compiles to `ConvExec::Reordered` — probes
/// both orders and stays bitwise identical to the default plan, because
/// reordered work items write disjoint output rows (order changes locality
/// only, never accumulation order).
#[test]
fn reordered_group_order_is_tuned_and_matches_default_bitwise() {
    use prt_dnn::dsl::op::{Activation, Op, PadMode};
    use prt_dnn::pruning::scheme::project_scheme;
    use prt_dnn::pruning::verify::apply_mask;
    use prt_dnn::util::rng::Rng;

    let mut rng = Rng::new(90);
    let mut g = Graph::new("reord-net");
    let x = g.add("x", Op::Input { shape: vec![1, 6, 12, 12] }, &[]);
    let c1 = g.add(
        "c1",
        Op::Conv2d {
            out_c: 16,
            in_c: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            pad_mode: PadMode::Zeros,
            fused_act: Activation::Relu,
        },
        &[x],
    );
    g.add("out", Op::Output, &[c1]);
    // Filter-prune c1 by hand: filter/channel schemes are what compile to
    // the reordered kernel (the stock apps use column/pattern).
    let w = Tensor::randn(&[16, 6, 3, 3], &mut rng);
    let scheme = project_scheme(&w, "filter", 0.5, None);
    g.set_param("c1.weight", apply_mask(&w, &scheme));
    let schemes = vec![("c1".to_string(), scheme)];

    for &threads in &[1usize, 4] {
        let base_cfg = ExecConfig::compact(threads, schemes.clone());
        let cache = tmp(&format!("reord-t{}", threads));
        let _ = std::fs::remove_file(&cache);
        let tuned_cfg = ExecConfig::compact(threads, schemes.clone())
            .with_tuning(TuneOpts::quick(&cache));

        let p0 = Planner::plan(&g, &base_cfg).unwrap();
        let p1 = Planner::plan(&g, &tuned_cfg).unwrap();
        assert_verified(&format!("reord t={} base", threads), &p0);
        assert_verified(&format!("reord t={} tuned", threads), &p1);
        assert!(p1.tuned());
        let sched = p1.schedules_json();
        assert!(
            sched.get("c1").as_obj().is_some(),
            "t={}: no schedule recorded for the reordered step: {}",
            threads,
            sched
        );
        assert!(
            sched.get("c1").get("group_order").as_str().is_some(),
            "t={}: schedule must serialize the group order: {}",
            threads,
            sched
        );

        let x = structured_input(&p0.input_shapes()[0]);
        let o0 = ExecContext::for_plan(&p0).run(&p0, std::slice::from_ref(&x)).unwrap();
        let o1 = ExecContext::for_plan(&p1).run(&p1, std::slice::from_ref(&x)).unwrap();
        assert_eq!(
            o0[0].data(),
            o1[0].data(),
            "t={}: tuned reordered schedule moved bits",
            threads
        );
        let _ = std::fs::remove_file(&cache);
    }
}

/// Plan-time fused compound steps are one more schedule axis (the ROADMAP
/// fusion item): on a graph whose conv absorbs an act + residual-add tail,
/// the default plan emits a compound step, the tuned plan searches the
/// fuse on/off axis (its cache keys carry the `|fa…` tail segment), and —
/// whichever side the micro-benchmarks pick — tuned, default and
/// `--no-fuse` plans all agree bit-for-bit.
#[test]
fn fused_steps_are_tuned_and_match_default_bitwise() {
    use prt_dnn::dsl::op::{Activation, Op, PadMode};
    use prt_dnn::util::rng::Rng;

    let mut rng = Rng::new(91);
    let mut g = Graph::new("fuse-net");
    let x = g.add("x", Op::Input { shape: vec![1, 6, 12, 12] }, &[]);
    let c1 = g.add(
        "c1",
        Op::Conv2d {
            out_c: 6,
            in_c: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            pad_mode: PadMode::Zeros,
            fused_act: Activation::Identity,
        },
        &[x],
    );
    g.set_param("c1.weight", Tensor::randn(&[6, 6, 3, 3], &mut rng));
    g.set_param("c1.bias", Tensor::randn(&[6], &mut rng).map(|v| v * 0.1));
    let a = g.add("a", Op::Act(Activation::Relu), &[c1]);
    let s = g.add("s", Op::Add, &[a, x]);
    g.add("out", Op::Output, &[s]);

    for &threads in &[1usize, 4] {
        let cache = tmp(&format!("fuse-t{}", threads));
        let _ = std::fs::remove_file(&cache);

        let p0 = Planner::plan(&g, &ExecConfig::dense(threads)).unwrap();
        assert_eq!(p0.fused_steps(), 1, "t={}: default plan must fuse the chain", threads);
        let p1 = Planner::plan(
            &g,
            &ExecConfig::dense(threads).with_tuning(TuneOpts::quick(&cache)),
        )
        .unwrap();
        assert!(p1.tuned());
        // The fusable request's cache key carries the tail segment — the
        // fuse axis is part of the persisted schedule space.
        let text = std::fs::read_to_string(&cache).unwrap();
        assert!(
            text.contains("|fa1r1"),
            "t={}: cache keys must carry the fuse-axis segment: {}",
            threads,
            text
        );
        let p2 = Planner::plan(&g, &ExecConfig::dense(threads).with_fuse(false)).unwrap();
        assert_eq!(p2.fused_steps(), 0);
        assert_verified(&format!("fuse t={} default", threads), &p0);
        assert_verified(&format!("fuse t={} tuned", threads), &p1);
        assert_verified(&format!("fuse t={} no-fuse", threads), &p2);

        let x = structured_input(&p0.input_shapes()[0]);
        let o0 = ExecContext::for_plan(&p0).run(&p0, std::slice::from_ref(&x)).unwrap();
        let o1 = ExecContext::for_plan(&p1).run(&p1, std::slice::from_ref(&x)).unwrap();
        let o2 = ExecContext::for_plan(&p2).run(&p2, std::slice::from_ref(&x)).unwrap();
        assert_eq!(o0[0].data(), o1[0].data(), "t={}: tuned fuse axis moved bits", threads);
        assert_eq!(o0[0].data(), o2[0].data(), "t={}: fused vs --no-fuse moved bits", threads);
        let _ = std::fs::remove_file(&cache);
    }
}

/// The cache's JSON form is deterministic: parse(serialize(c)) == c and a
/// second serialization is byte-identical (sorted keys, stable number
/// formatting) — warm caches diff cleanly across runs.
#[test]
fn tune_cache_roundtrips_through_json_deterministically() {
    let mut c = TuneCache::new();
    c.insert("conv|pattern|m48k108n1024|k3s1p1|t4", Schedule::default());
    c.insert(
        "conv|dense|m16k3n4096|k1s1p0|t4",
        Schedule {
            lowering: prt_dnn::tuner::Lowering::Direct,
            mc: 32,
            kc: 512,
            nc: 4096,
            split: prt_dnn::tuner::SplitAxis::Cols,
            unroll: 1,
            ..Schedule::default()
        },
    );
    let s1 = c.to_json().to_string_pretty();
    let parsed = TuneCache::from_json(&Json::parse(&s1).unwrap()).unwrap();
    assert_eq!(parsed, c, "parse(serialize(c)) != c");
    let s2 = parsed.to_json().to_string_pretty();
    assert_eq!(s1, s2, "re-serialization not byte-identical");

    // And through a real file.
    let p = tmp("cache-file");
    c.save(&p).unwrap();
    let loaded = TuneCache::load(&p).unwrap();
    assert_eq!(loaded.to_json().to_string_pretty(), s1);
    let _ = std::fs::remove_file(&p);
}

/// CI smoke: tiny width-0.25 graph, 2-candidate space. The first plan
/// populates the cache (benchmarks ran); a second plan against the warm
/// cache performs zero micro-benchmark runs and answers every key from
/// the cache.
#[test]
fn tuner_smoke_cache_hit_on_second_plan() {
    let cache = tmp("smoke");
    let _ = std::fs::remove_file(&cache);
    let mut g = build_style(32, 0.25, 77);
    let schemes = prune_graph(&mut g, &AppSpec::for_app("style"));
    let opts = TuneOpts {
        enabled: true,
        cache_path: Some(cache.clone()),
        max_candidates: 2, // default + best roofline-ranked challenger
        bench_repeats: 1,
    };
    let cfg = ExecConfig::compact(2, schemes).with_tuning(opts);

    let p1 = Planner::plan(&g, &cfg).unwrap();
    assert_verified("smoke cold", &p1);
    assert!(p1.tuned());
    let s1 = p1.tune_stats();
    assert!(s1.cache_misses > 0, "cold cache must miss");
    assert!(s1.bench_runs > 0, "cold cache must micro-benchmark");
    assert!(cache.exists(), "cache file not written");

    let p2 = Planner::plan(&g, &cfg).unwrap();
    assert_verified("smoke warm", &p2);
    let s2 = p2.tune_stats();
    assert_eq!(s2.bench_runs, 0, "warm cache must perform zero benchmark runs");
    assert_eq!(s2.cache_misses, 0, "warm cache must not miss");
    assert!(s2.cache_hits > 0, "warm cache must hit");

    // Both plans carry identical per-step schedules, and the plan-side
    // serialization exposes them.
    let j1 = p1.schedules_json().to_string();
    let j2 = p2.schedules_json().to_string();
    assert_eq!(j1, j2, "cached schedules differ from searched ones");
    assert!(!p1.schedules_json().as_obj().unwrap().is_empty());
    let _ = std::fs::remove_file(&cache);
}
