//! Acceptance check for the static memory planner **and the persistent
//! compute pool**, driven through the `session` front door: a plan built
//! by `Model::session().…().build()` still executes with **zero heap
//! allocations** in steady state — at `threads = 1` and at `threads = 4`,
//! for single-frame **and batched** plans (batch = 4), including plans
//! carrying **fused compound steps** (plan-time operator fusion is on by
//! default) — and two consecutive runs allocate no new arena bytes.
//!
//! A counting global allocator wraps the system allocator; the measured
//! loop takes the minimum over several trials so unrelated background
//! allocation (test harness bookkeeping) cannot flake the assertion.
//! The allocation-free loop itself is `ExecContext::run_into` on a
//! context built from the session's plan — `Session::run` returns owned
//! output tensors by design, so the zero-alloc serving path is plan +
//! private context, exactly what the coordinator workers do.

use prt_dnn::apps::builders::{build_coloring, build_sr, build_style};
use prt_dnn::apps::{AppSpec, Variant};
use prt_dnn::dsl::Graph;
use prt_dnn::executor::ExecContext;
use prt_dnn::pruning::scheme::project_scheme;
use prt_dnn::pruning::verify::apply_mask;
use prt_dnn::session::{Model, Quantization, Session};
use prt_dnn::tensor::Tensor;
use prt_dnn::tuner::TuneOpts;
use prt_dnn::util::alloc_count::{alloc_count, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Minimum allocation count for one `run_into` frame over `trials` trials.
fn min_allocs_per_frame(
    ctx: &mut ExecContext,
    plan: &prt_dnn::executor::ExecutionPlan,
    x: &Tensor,
    outs: &mut [Tensor],
    trials: usize,
) -> usize {
    let mut min = usize::MAX;
    for _ in 0..trials {
        let before = alloc_count();
        ctx.run_into(plan, std::slice::from_ref(x), outs).unwrap();
        let delta = alloc_count() - before;
        min = min.min(delta);
    }
    min
}

fn assert_zero_alloc(tag: &str, session: &Session) {
    let plan = session.plan();
    // Pool workers spawn here — at construction, never per frame.
    let mut ctx = ExecContext::for_plan(plan);
    assert_eq!(ctx.pool().threads(), session.threads(), "{}: pool size", tag);
    let mut outs: Vec<Tensor> =
        plan.output_shapes().iter().map(|s| Tensor::zeros(s)).collect();
    let x = Tensor::full(&session.shapes().inputs[0], 0.5);

    // Warm up (first frames may touch lazily initialised state: OS mutex /
    // condvar internals, thread-locals), then assert the arena is already
    // exactly plan-sized and stays that way.
    ctx.run_into(plan, std::slice::from_ref(&x), &mut outs).unwrap();
    let (arena0, scratch0) = (ctx.arena_len(), ctx.scratch_len());
    assert_eq!(arena0, plan.arena_len(), "{}: arena != plan size", tag);
    assert!(scratch0 >= plan.scratch_len(), "{}: scratch undersized", tag);

    let min = min_allocs_per_frame(&mut ctx, plan, &x, &mut outs, 3);
    assert_eq!(
        min, 0,
        "{}: steady-state run_into allocated {} times per frame",
        tag, min
    );

    // Two consecutive runs allocate no new arena bytes.
    ctx.run_into(plan, std::slice::from_ref(&x), &mut outs).unwrap();
    assert_eq!(ctx.arena_len(), arena0, "{}: arena grew between frames", tag);
    assert_eq!(ctx.scratch_len(), scratch0, "{}: scratch grew between frames", tag);
}

/// Session for one app variant over a custom-scale graph.
fn variant_session(base: &Graph, app: &str, variant: Variant, threads: usize) -> Session {
    Model::from_graph(base, &AppSpec::for_app(app), variant)
        .session()
        .threads(threads)
        .build()
        .unwrap()
}

/// Prune in place and wrap without running passes — the historical
/// compact configuration this suite has always measured (pass-fused
/// graphs are covered by the session/tuner equivalence suites).
fn pruned_compact_model(mut g: Graph, app: &str) -> Model {
    let schemes = prt_dnn::apps::prune_graph(&mut g, &AppSpec::for_app(app));
    assert!(!schemes.is_empty(), "{}: nothing pruned", app);
    Model::from_compiled(g, schemes)
}

/// The `Reordered`-fallback session: a filter scheme has no declared
/// column/pattern structure, so the planner compiles the filter-signature
/// reorder kernel (per-group gather panels).
fn reordered_fallback_model(seed: u64) -> Model {
    let mut g = build_style(48, 0.25, seed);
    let name = "res0_c1";
    let w = g.param(&format!("{}.weight", name)).unwrap().clone();
    let s = project_scheme(&w, "filter", 0.5, None);
    g.set_param(format!("{}.weight", name), apply_mask(&w, &s));
    Model::from_compiled(g, vec![(name.to_string(), s)])
}

/// One test fn on purpose: the allocation counter is process-global, so
/// concurrently running sibling tests (the default harness behaviour)
/// would allocate inside each other's measurement windows and flake the
/// `min == 0` assertion. Serializing all configurations inside a single
/// test keeps the counter quiet during every measured frame. (The pool's
/// own worker threads are quiet too: steady-state dispatch only spins or
/// parks on a condvar.)
#[test]
fn steady_state_is_allocation_free() {
    for &threads in &[1usize, 4] {
        // Dense baseline.
        let g = build_style(48, 0.25, 51);
        assert_zero_alloc(
            &format!("style/dense/t{}", threads),
            &variant_session(&g, "style", Variant::Unpruned, threads),
        );

        // Style transfer uses column pruning → ColumnCompact kernels.
        let model = pruned_compact_model(build_style(48, 0.25, 52), "style");
        assert_zero_alloc(
            &format!("style/compact/t{}", threads),
            &model.session().threads(threads).build().unwrap(),
        );

        // Coloring uses pattern pruning → PatternPlan kernels.
        let model = pruned_compact_model(build_coloring(48, 0.25, 53), "coloring");
        assert_zero_alloc(
            &format!("coloring/compact/t{}", threads),
            &model.session().threads(threads).build().unwrap(),
        );

        // Super resolution: pattern pruning + pixel shuffle tail.
        let model = pruned_compact_model(build_sr(24, 4, 0.25, 54), "sr");
        assert_zero_alloc(
            &format!("sr/compact/t{}", threads),
            &model.session().threads(threads).build().unwrap(),
        );

        // The `Reordered` fallback: its per-group activation panels come
        // out of the plan-sized scratch, so even this path allocates
        // nothing.
        let session = reordered_fallback_model(55)
            .session()
            .threads(threads)
            .build()
            .unwrap();
        assert_zero_alloc(&format!("style/reordered-fallback/t{}", threads), &session);
    }

    // Batched plans (batch = 4, threads = 4): the arena/scratch ranges
    // scale by the batch at plan time, the packed input is one tensor, and
    // the kernels dispatch once over the combined 4 × rows space — still
    // zero allocations per (batched) frame on all three apps and on the
    // Reordered-fallback panel path. Fusion is on by default, and these
    // uncompiled graphs keep their standalone act / residual-add tails, so
    // each session's plan carries compound fused steps — the fused
    // epilogue (and its residual read) must be as allocation-free as the
    // steps it absorbed.
    {
        let model = pruned_compact_model(build_style(48, 0.25, 61), "style");
        let s = model.session().threads(4).batch(4).build().unwrap();
        assert!(s.fused_steps() > 0, "style/b4: plan must carry fused steps");
        assert_zero_alloc("style/compact/fused/b4/t4", &s);

        let model = pruned_compact_model(build_coloring(48, 0.25, 62), "coloring");
        let s = model.session().threads(4).batch(4).build().unwrap();
        assert!(s.fused_steps() > 0, "coloring/b4: plan must carry fused steps");
        assert_zero_alloc("coloring/compact/fused/b4/t4", &s);

        let model = pruned_compact_model(build_sr(24, 4, 0.25, 63), "sr");
        let s = model.session().threads(4).batch(4).build().unwrap();
        assert!(s.fused_steps() > 0, "sr/b4: plan must carry fused steps");
        assert_zero_alloc("sr/compact/fused/b4/t4", &s);

        // Reordered fallback at batch 4: the per-group activation panels
        // stay per pool thread (not per sample), pre-sized by the plan.
        let s = reordered_fallback_model(64).session().threads(4).batch(4).build().unwrap();
        assert_zero_alloc("style/reordered-fallback/b4/t4", &s);
    }

    // Int8 sessions: the i8 patch + i32 accumulator buffers are plan-sized
    // (`qpatch_len` / `qacc_len`) and live in the context's quant scratch,
    // so the per-dispatch quantize → i8 GEMM/SpMM → requantize round trip
    // is as allocation-free as the f32 path it shadows — across storage
    // formats, thread counts and batched plans.
    {
        for &threads in &[1usize, 4] {
            let model = pruned_compact_model(build_style(48, 0.25, 71), "style");
            let s = model
                .session()
                .threads(threads)
                .quantize(Quantization::Int8)
                .build()
                .unwrap();
            assert!(s.plan().quantized(), "int8 plan must report quantized");
            assert_zero_alloc(&format!("style/int8-compact/t{}", threads), &s);
        }

        // Dense int8 at batch 4 (the QDense GEMM path, batched).
        let g = build_style(48, 0.25, 72);
        let s = Model::from_graph(&g, &AppSpec::for_app("style"), Variant::Unpruned)
            .session()
            .threads(4)
            .batch(4)
            .quantize(Quantization::Int8)
            .build()
            .unwrap();
        assert_zero_alloc("style/int8-dense/b4/t4", &s);

        // CSR int8 (the QCsr SpMM path).
        let g = build_coloring(48, 0.25, 73);
        let s = Model::from_graph(&g, &AppSpec::for_app("coloring"), Variant::Pruned)
            .session()
            .threads(4)
            .quantize(Quantization::Int8)
            .build()
            .unwrap();
        assert_zero_alloc("coloring/int8-csr/t4", &s);
    }

    // A tuned plan loaded from a warm cache is equally allocation-free:
    // warm the cache once, then measure a session that answered every key
    // from it (tuning work happens at plan time, never per frame).
    let cache = std::env::temp_dir()
        .join(format!("prt-zero-alloc-tune-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&cache);
    let model = pruned_compact_model(build_style(48, 0.25, 57), "style");
    let warm = model
        .session()
        .threads(4)
        .tune(TuneOpts::quick(&cache))
        .build()
        .unwrap();
    assert!(warm.plan().tuned() && warm.plan().tune_stats().bench_runs > 0);
    let tuned = model
        .session()
        .threads(4)
        .tune(TuneOpts::quick(&cache))
        .build()
        .unwrap();
    assert_eq!(tuned.plan().tune_stats().bench_runs, 0, "cache must be warm");
    assert_zero_alloc("style/tuned-warm-cache/t4", &tuned);
    let _ = std::fs::remove_file(&cache);
}
