//! Acceptance check for the static memory planner **and the persistent
//! compute pool**: steady-state `ExecContext::run_into` performs **zero
//! heap allocations** — at `threads = 1` and at `threads = 4`, for
//! single-frame **and batched** plans (batch = 4) — and two consecutive
//! runs allocate no new arena bytes.
//!
//! A counting global allocator wraps the system allocator; the measured
//! loop takes the minimum over several trials so unrelated background
//! allocation (test harness bookkeeping) cannot flake the assertion.
//! Multi-threaded kernels fork-join on the context's pool (spawned once
//! at `ExecContext::for_plan`), passing the closure by reference through
//! the pool's task slot — so even at `threads = 4` a frame allocates
//! nothing: no thread spawns, no boxed jobs, no channel nodes.

use prt_dnn::apps::builders::{build_coloring, build_sr, build_style};
use prt_dnn::apps::{prune_graph, AppSpec};
use prt_dnn::executor::{ExecConfig, ExecContext, Planner};
use prt_dnn::pruning::scheme::project_scheme;
use prt_dnn::pruning::verify::apply_mask;
use prt_dnn::tensor::Tensor;
use prt_dnn::tuner::TuneOpts;
use prt_dnn::util::alloc_count::{alloc_count, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Minimum allocation count for one `run_into` frame over `trials` trials.
fn min_allocs_per_frame(
    ctx: &mut ExecContext,
    plan: &prt_dnn::executor::ExecutionPlan,
    x: &Tensor,
    outs: &mut [Tensor],
    trials: usize,
) -> usize {
    let mut min = usize::MAX;
    for _ in 0..trials {
        let before = alloc_count();
        ctx.run_into(plan, std::slice::from_ref(x), outs).unwrap();
        let delta = alloc_count() - before;
        min = min.min(delta);
    }
    min
}

fn assert_zero_alloc(tag: &str, g: &prt_dnn::dsl::Graph, cfg: &ExecConfig) {
    let plan = Planner::plan(g, cfg).unwrap();
    // Pool workers spawn here — at construction, never per frame.
    let mut ctx = ExecContext::for_plan(&plan);
    assert_eq!(ctx.pool().threads(), cfg.threads.max(1), "{}: pool size", tag);
    let mut outs: Vec<Tensor> =
        plan.output_shapes().iter().map(|s| Tensor::zeros(s)).collect();
    let x = Tensor::full(&plan.input_shapes()[0], 0.5);

    // Warm up (first frames may touch lazily initialised state: OS mutex /
    // condvar internals, thread-locals), then assert the arena is already
    // exactly plan-sized and stays that way.
    ctx.run_into(&plan, std::slice::from_ref(&x), &mut outs).unwrap();
    let (arena0, scratch0) = (ctx.arena_len(), ctx.scratch_len());
    assert_eq!(arena0, plan.arena_len(), "{}: arena != plan size", tag);
    assert!(scratch0 >= plan.scratch_len(), "{}: scratch undersized", tag);

    let min = min_allocs_per_frame(&mut ctx, &plan, &x, &mut outs, 3);
    assert_eq!(
        min, 0,
        "{}: steady-state run_into allocated {} times per frame",
        tag, min
    );

    // Two consecutive runs allocate no new arena bytes.
    ctx.run_into(&plan, std::slice::from_ref(&x), &mut outs).unwrap();
    assert_eq!(ctx.arena_len(), arena0, "{}: arena grew between frames", tag);
    assert_eq!(ctx.scratch_len(), scratch0, "{}: scratch grew between frames", tag);
}

/// One test fn on purpose: the allocation counter is process-global, so
/// concurrently running sibling tests (the default harness behaviour)
/// would allocate inside each other's measurement windows and flake the
/// `min == 0` assertion. Serializing all configurations inside a single
/// test keeps the counter quiet during every measured frame. (The pool's
/// own worker threads are quiet too: steady-state dispatch only spins or
/// parks on a condvar.)
#[test]
fn steady_state_is_allocation_free() {
    for &threads in &[1usize, 4] {
        // Dense baseline.
        let g = build_style(48, 0.25, 51);
        assert_zero_alloc(
            &format!("style/dense/t{}", threads),
            &g,
            &ExecConfig::dense(threads),
        );

        // Style transfer uses column pruning → ColumnCompact kernels.
        let mut g = build_style(48, 0.25, 52);
        let schemes = prune_graph(&mut g, &AppSpec::for_app("style"));
        assert!(!schemes.is_empty());
        assert_zero_alloc(
            &format!("style/compact/t{}", threads),
            &g,
            &ExecConfig::compact(threads, schemes),
        );

        // Coloring uses pattern pruning → PatternPlan kernels.
        let mut g = build_coloring(48, 0.25, 53);
        let schemes = prune_graph(&mut g, &AppSpec::for_app("coloring"));
        assert!(!schemes.is_empty());
        assert_zero_alloc(
            &format!("coloring/compact/t{}", threads),
            &g,
            &ExecConfig::compact(threads, schemes),
        );

        // Super resolution: pattern pruning + pixel shuffle tail.
        let mut g = build_sr(24, 4, 0.25, 54);
        let schemes = prune_graph(&mut g, &AppSpec::for_app("sr"));
        assert!(!schemes.is_empty());
        assert_zero_alloc(
            &format!("sr/compact/t{}", threads),
            &g,
            &ExecConfig::compact(threads, schemes),
        );

        // The `Reordered` fallback (filter scheme → filter-signature
        // reorder): its per-group activation panels now come out of the
        // plan-sized scratch, so even this path allocates nothing.
        let mut g = build_style(48, 0.25, 55);
        let name = "res0_c1";
        let w = g.param(&format!("{}.weight", name)).unwrap().clone();
        let s = project_scheme(&w, "filter", 0.5, None);
        g.set_param(format!("{}.weight", name), apply_mask(&w, &s));
        let schemes = vec![(name.to_string(), s)];
        assert_zero_alloc(
            &format!("style/reordered-fallback/t{}", threads),
            &g,
            &ExecConfig::compact(threads, schemes),
        );
    }

    // Batched plans (batch = 4, threads = 4): the arena/scratch ranges
    // scale by the batch at plan time, the packed input is one tensor, and
    // the kernels dispatch once over the combined 4 × rows space — still
    // zero allocations per (batched) frame on all three apps and on the
    // Reordered-fallback panel path.
    {
        let mut g = build_style(48, 0.25, 61);
        let schemes = prune_graph(&mut g, &AppSpec::for_app("style"));
        assert_zero_alloc(
            "style/compact/b4/t4",
            &g,
            &ExecConfig::compact(4, schemes).with_batch(4),
        );

        let mut g = build_coloring(48, 0.25, 62);
        let schemes = prune_graph(&mut g, &AppSpec::for_app("coloring"));
        assert_zero_alloc(
            "coloring/compact/b4/t4",
            &g,
            &ExecConfig::compact(4, schemes).with_batch(4),
        );

        let mut g = build_sr(24, 4, 0.25, 63);
        let schemes = prune_graph(&mut g, &AppSpec::for_app("sr"));
        assert_zero_alloc(
            "sr/compact/b4/t4",
            &g,
            &ExecConfig::compact(4, schemes).with_batch(4),
        );

        // Reordered fallback at batch 4: the per-group activation panels
        // stay per pool thread (not per sample), pre-sized by the plan.
        let mut g = build_style(48, 0.25, 64);
        let name = "res0_c1";
        let w = g.param(&format!("{}.weight", name)).unwrap().clone();
        let s = project_scheme(&w, "filter", 0.5, None);
        g.set_param(format!("{}.weight", name), apply_mask(&w, &s));
        assert_zero_alloc(
            "style/reordered-fallback/b4/t4",
            &g,
            &ExecConfig::compact(4, vec![(name.to_string(), s)]).with_batch(4),
        );
    }

    // A tuned plan loaded from a warm cache is equally allocation-free:
    // warm the cache once, then measure a plan that answered every key
    // from it (tuning work happens at plan time, never per frame).
    let cache = std::env::temp_dir()
        .join(format!("prt-zero-alloc-tune-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&cache);
    let mut g = build_style(48, 0.25, 57);
    let schemes = prune_graph(&mut g, &AppSpec::for_app("style"));
    let cfg =
        ExecConfig::compact(4, schemes).with_tuning(TuneOpts::quick(&cache));
    let warm = Planner::plan(&g, &cfg).unwrap();
    assert!(warm.tuned() && warm.tune_stats().bench_runs > 0);
    assert_zero_alloc("style/tuned-warm-cache/t4", &g, &cfg);
    let _ = std::fs::remove_file(&cache);
}
