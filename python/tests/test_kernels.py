"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (K1).

Hypothesis sweeps shapes/sparsities; assert_allclose against ref.py is THE
core correctness signal for the compute hot path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    build_groups,
    column_pruned_matmul,
    matmul_pallas,
    pattern_grouped_matmul,
)
from compile.kernels.ref import (
    column_pruned_matmul_ref,
    conv2d_ref,
    im2col_ref,
    matmul_ref,
    pattern_grouped_matmul_ref,
)
from compile.pruning import project

SETTINGS = dict(max_examples=12, deadline=None)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 90),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_pallas_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k), dtype=np.float32)
    b = rng.standard_normal((k, n), dtype=np.float32)
    got = matmul_pallas(jnp.asarray(a), jnp.asarray(b))
    want = matmul_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(2, 32),
    k=st.integers(8, 72),
    n=st.integers(1, 48),
    frac=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_column_pruned_matmul_matches_ref(m, k, n, frac, seed):
    rng = np.random.default_rng(seed)
    kp = max(int(k * frac), 1)
    keep = np.sort(rng.choice(k, size=kp, replace=False)).astype(np.int32)
    w_packed = rng.standard_normal((m, kp), dtype=np.float32)
    x = rng.standard_normal((k, n), dtype=np.float32)
    got = column_pruned_matmul(jnp.asarray(w_packed), jnp.asarray(keep), jnp.asarray(x))
    want = column_pruned_matmul_ref(jnp.asarray(w_packed), keep, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    # And against the scatter-to-dense definition.
    w_full = np.zeros((m, k), dtype=np.float32)
    w_full[:, keep] = w_packed
    dense = matmul_ref(jnp.asarray(w_full), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    o=st.integers(4, 16),
    i=st.integers(1, 6),
    n=st.integers(1, 40),
    sparsity=st.floats(0.4, 0.8),
    seed=st.integers(0, 2**31 - 1),
)
def test_pattern_grouped_matmul_matches_ref(o, i, n, sparsity, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((o, i, 3, 3), dtype=np.float32)
    wp, _ = project(w, "pattern", sparsity)
    wm = wp.reshape(o, i * 9)
    groups = build_groups(wm)
    x = rng.standard_normal((i * 9, n), dtype=np.float32)
    got = pattern_grouped_matmul(groups, jnp.asarray(x), o)
    want = pattern_grouped_matmul_ref(groups, jnp.asarray(x), o)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    dense = matmul_ref(jnp.asarray(wm), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,pad,pad_mode", [(1, 1, "zeros"), (2, 1, "zeros"), (1, 1, "reflect"), (1, 4, "reflect")])
def test_im2col_conv_matches_lax(stride, pad, pad_mode):
    """The im2col+GEMM conv oracle agrees with lax.conv (zeros) / padded
    lax.conv (reflect)."""
    rng = np.random.default_rng(0)
    k = 2 * pad + 1
    x = rng.standard_normal((2, 3, 12, 12), dtype=np.float32)
    w = rng.standard_normal((5, 3, k, k), dtype=np.float32)
    got = conv2d_ref(jnp.asarray(x), jnp.asarray(w), stride=stride, pad=pad, pad_mode=pad_mode)
    xp = jnp.asarray(x)
    if pad > 0:
        mode = "reflect" if pad_mode == "reflect" else "constant"
        xp = jnp.pad(xp, ((0, 0), (0, 0), (pad, pad), (pad, pad)), mode=mode)
    want = jax.lax.conv_general_dilated(
        xp, jnp.asarray(w), (stride, stride), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_im2col_row_order_matches_rust_convention():
    """Row index = (c*kh + r)*kw + s — the layout rust kernels assume."""
    x = jnp.arange(2 * 3 * 3, dtype=jnp.float32).reshape(2, 3, 3)
    patches, (oh, ow) = im2col_ref(x, 1, 1, 1, 0)
    assert (oh, ow) == (3, 3)
    np.testing.assert_array_equal(np.asarray(patches), np.asarray(x).reshape(2, 9))


def test_matmul_pallas_pads_tiny_shapes():
    a = jnp.ones((1, 1), jnp.float32)
    b = jnp.full((1, 1), 3.0, jnp.float32)
    out = matmul_pallas(a, b)
    assert out.shape == (1, 1)
    assert float(out[0, 0]) == 3.0


def test_empty_groups_give_zero_output():
    x = jnp.ones((9, 4), jnp.float32)
    out = pattern_grouped_matmul([], x, 3)
    assert out.shape == (3, 4)
    assert float(jnp.abs(out).sum()) == 0.0
