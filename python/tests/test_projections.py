"""Projection operators: structure + density guarantees (the sets S_i)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.pruning import PCONV_PATTERNS, project

SETTINGS = dict(max_examples=15, deadline=None)


@settings(**SETTINGS)
@given(
    o=st.integers(2, 24),
    i=st.integers(1, 12),
    sparsity=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_column_projection_structure(o, i, sparsity, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((o, i, 3, 3), dtype=np.float32)
    wp, meta = project(w, "column", sparsity)
    m = wp.reshape(o, -1)
    keep = meta["keep"]
    # Kept columns identical to original, others zero.
    np.testing.assert_array_equal(m[:, keep], w.reshape(o, -1)[:, keep])
    dropped = [c for c in range(m.shape[1]) if c not in set(keep)]
    assert np.all(m[:, dropped] == 0)
    # Density close to target.
    target = 1.0 - sparsity
    got = len(keep) / m.shape[1]
    assert abs(got - target) <= 1.0 / m.shape[1] + 1e-9


@settings(**SETTINGS)
@given(
    o=st.integers(4, 20),
    i=st.integers(2, 8),
    sparsity=st.floats(0.3, 0.8),
    seed=st.integers(0, 2**31 - 1),
)
def test_pattern_projection_structure(o, i, sparsity, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((o, i, 3, 3), dtype=np.float32)
    wp, meta = project(w, "pattern", sparsity)
    ids = np.asarray(meta["ids"], dtype=np.int64)
    for oo in range(o):
        for ii in range(i):
            kern = wp[oo, ii].reshape(9)
            if ids[oo, ii] == 255:
                assert np.all(kern == 0)
            else:
                pat = set(PCONV_PATTERNS[ids[oo, ii]])
                nz = set(np.nonzero(kern)[0].tolist())
                assert nz.issubset(pat), f"kernel support {nz} not in pattern {pat}"


def test_filter_and_channel_projection():
    rng = np.random.default_rng(3)
    w = rng.standard_normal((8, 6, 3, 3), dtype=np.float32)
    wf, meta_f = project(w, "filter", 0.5)
    for o in range(8):
        row = wf[o].reshape(-1)
        if o in meta_f["keep"]:
            np.testing.assert_array_equal(row, w[o].reshape(-1))
        else:
            assert np.all(row == 0)
    wc, meta_c = project(w, "channel", 0.5)
    for c in range(6):
        blk = wc[:, c]
        if c in meta_c["keep"]:
            np.testing.assert_array_equal(blk, w[:, c])
        else:
            assert np.all(blk == 0)


def test_projection_is_idempotent():
    rng = np.random.default_rng(4)
    w = rng.standard_normal((8, 4, 3, 3), dtype=np.float32)
    for kind in ("column", "filter", "channel", "pattern"):
        wp, _ = project(w, kind, 0.6)
        wp2, _ = project(wp, kind, 0.6)
        np.testing.assert_allclose(wp2, wp, atol=0)


def test_projection_minimises_distance_column():
    """The projection keeps the max-norm columns — any other same-size
    support is farther in Frobenius norm."""
    rng = np.random.default_rng(5)
    w = rng.standard_normal((6, 2, 3, 3), dtype=np.float32)
    wp, meta = project(w, "column", 0.5)
    dist = np.linalg.norm(w - wp)
    m = w.reshape(6, -1)
    cols = m.shape[1]
    keep_n = len(meta["keep"])
    for trial in range(10):
        alt = np.sort(rng.choice(cols, size=keep_n, replace=False))
        alt_w = np.zeros_like(m)
        alt_w[:, alt] = m[:, alt]
        assert np.linalg.norm(m - alt_w) >= dist - 1e-5


def test_pattern_requires_3x3():
    w = np.zeros((4, 4, 5, 5), dtype=np.float32)
    with pytest.raises(AssertionError):
        project(w, "pattern", 0.5)
