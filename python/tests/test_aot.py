"""AOT pipeline: HLO text + graph JSON + manifest round out correctly."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

PY_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    res = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--apps",
            "super_resolution",
        ],
        cwd=PY_DIR,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return out


def test_manifest_schema(artifact_dir):
    with open(artifact_dir / "manifest.json") as f:
        m = json.load(f)
    assert m["format"] == "prt-dnn-artifacts"
    names = {(e["name"], e["variant"]) for e in m["models"]}
    assert ("super_resolution", "dense") in names
    assert ("super_resolution", "pruned") in names
    for e in m["models"]:
        assert (artifact_dir / e["hlo"]).exists()
        assert e["inputs"] and e["outputs"]


def test_hlo_is_text_module(artifact_dir):
    hlo = (artifact_dir / "super_resolution.hlo.txt").read_text()
    assert hlo.startswith("HloModule"), hlo[:80]
    assert "ROOT" in hlo
    # The output is a tuple (return_tuple=True) for the rust unwrapper.
    assert "tuple" in hlo


def test_graph_json_exported(artifact_dir):
    with open(artifact_dir / "super_resolution.graph.json") as f:
        g = json.load(f)
    assert g["format"] == "prt-dnn-graph"
    assert g["nodes"][0]["op"] == "input"
    # Every referenced weight file exists and loads as f32.
    for key, rel in g["params"].items():
        arr = np.load(artifact_dir / rel)
        assert arr.dtype == np.float32, key


def test_pruned_artifact_differs_from_dense(artifact_dir):
    dense = (artifact_dir / "super_resolution.hlo.txt").read_text()
    pruned = (artifact_dir / "super_resolution_pruned.hlo.txt").read_text()
    # Same program structure, different baked-in constants.
    assert dense != pruned
