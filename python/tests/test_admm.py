"""ADMM pruning (experiment A1 at test scale): exact structure + small
loss delta, and ADMM ≥ magnitude baseline on the distillation objective."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.pruning import admm_prune, AdmmConfig, magnitude_prune, project
from compile.pruning.projections import PCONV_PATTERNS


def tiny_problem(seed=0, kind="column", sparsity=0.6):
    """A 2-layer conv distillation problem small enough for CI.

    The teacher is *exactly structured* (column/pattern pruned), so a
    pruned student can represent it — what makes "small loss delta after
    ADMM" a meaningful assertion. The student starts at teacher + noise.
    """
    rng = np.random.default_rng(seed)
    wt1, _ = project(
        rng.standard_normal((8, 3, 3, 3)).astype(np.float32) * 0.3, kind, sparsity
    )
    wt2, _ = project(
        rng.standard_normal((3, 8, 3, 3)).astype(np.float32) * 0.3,
        kind if kind != "pattern" else "column",  # 3-filter head: column
        sparsity,
    )
    teacher = {"c1.weight": jnp.asarray(wt1), "c2.weight": jnp.asarray(wt2)}
    noise = lambda w: jnp.asarray(
        np.asarray(w) + rng.standard_normal(w.shape).astype(np.float32) * 0.05
    )
    params = {k: noise(v) for k, v in teacher.items()}
    x = jnp.asarray(rng.standard_normal((2, 3, 8, 8), dtype=np.float32))

    def fwd(p, xx):
        h = jax.lax.conv_general_dilated(
            xx, p["c1.weight"], (1, 1), "SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        h = jax.nn.relu(h)
        return jax.lax.conv_general_dilated(
            h, p["c2.weight"], (1, 1), "SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))

    y_ref = fwd(teacher, x)

    def loss(p):
        return jnp.mean((fwd(p, x) - y_ref) ** 2)

    return params, loss


def test_admm_reaches_exact_structure_column():
    params, loss = tiny_problem(0)
    schemes = {"c1.weight": ("column", 0.6), "c2.weight": ("column", 0.6)}
    cfg = AdmmConfig(rho=0.5, lr=1e-2, admm_iters=4, sgd_steps_per_iter=15, finetune_steps=30)
    pruned, masks, cfg = admm_prune(loss, params, schemes, cfg)
    for k in schemes:
        w = np.asarray(pruned[k])
        # Exact structure: re-projecting is a no-op.
        wp, _ = project(w, "column", 0.6)
        np.testing.assert_array_equal(w, wp)
        assert np.mean(w == 0) >= 0.55
    # Loss delta stays small (distillation of its own dense outputs).
    assert float(loss(pruned)) < 0.08


def test_admm_converges_near_constraint_set():
    """The W iterate must end *close* to its constraint set (small primal
    residual relative to the weight norm) — the convergence property ADMM
    provides that one-shot projection does not need."""
    params, loss = tiny_problem(1)
    schemes = {"c1.weight": ("column", 0.5)}
    cfg = AdmmConfig(rho=0.5, lr=1e-2, admm_iters=6, sgd_steps_per_iter=15, finetune_steps=5)
    _, _, cfg = admm_prune(loss, params, schemes, cfg)
    residuals = [e["primal_residual"] for e in cfg.log if e["iter"] != "final"]
    w_norm = float(np.linalg.norm(np.asarray(params["c1.weight"])))
    # Bounded (no divergence) and small relative to ||W||.
    assert max(residuals) < w_norm, f"residuals {residuals} vs ||W||={w_norm}"
    assert residuals[-1] / w_norm < 0.25, f"final relative residual {residuals[-1] / w_norm}"


def test_admm_beats_or_matches_magnitude():
    params, loss = tiny_problem(2)
    schemes = {"c1.weight": ("column", 0.7), "c2.weight": ("column", 0.7)}
    cfg = AdmmConfig(rho=0.5, lr=1e-2, admm_iters=4, sgd_steps_per_iter=12, finetune_steps=20)
    admm_p, _, _ = admm_prune(loss, params, schemes, cfg)
    mag_p, _, mag_loss = magnitude_prune(loss, params, schemes, finetune_steps=20)
    admm_loss = float(loss(admm_p))
    # ADMM's soft constraint lets weights migrate before hard pruning; it
    # should not be meaningfully worse than one-shot magnitude pruning.
    assert admm_loss <= mag_loss * 1.5 + 1e-4, (admm_loss, mag_loss)


def test_admm_pattern_scheme():
    params, loss = tiny_problem(3)
    schemes = {"c1.weight": ("pattern", 0.6)}
    cfg = AdmmConfig(rho=0.5, lr=1e-2, admm_iters=2, sgd_steps_per_iter=8, finetune_steps=10)
    pruned, masks, _ = admm_prune(loss, params, schemes, cfg)
    w = np.asarray(pruned["c1.weight"])
    pats = [set(p) for p in PCONV_PATTERNS]
    for o in range(w.shape[0]):
        for i in range(w.shape[1]):
            nz = set(np.nonzero(w[o, i].reshape(9))[0].tolist())
            assert nz == set() or any(nz.issubset(p) for p in pats)
