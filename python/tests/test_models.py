"""L2 model tests: shapes, Pallas-path vs lax-path equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.models import MODELS


@pytest.mark.parametrize(
    "key,in_shape,out_shape",
    [
        ("style_transfer", (1, 3, 32, 32), (1, 3, 32, 32)),
        ("coloring", (1, 1, 32, 32), (1, 3, 32, 32)),
        ("super_resolution", (1, 3, 16, 16), (1, 3, 64, 64)),
    ],
)
def test_model_shapes(key, in_shape, out_shape):
    init, forward, _ = MODELS[key]
    params = init(jax.random.PRNGKey(0), 0.25)
    x = jnp.ones(in_shape, jnp.float32) * 0.5
    y = forward(params, x, use_kernel=False)
    assert y.shape == out_shape


@pytest.mark.parametrize("key,in_shape", [
    ("style_transfer", (1, 3, 16, 16)),
    ("coloring", (1, 1, 16, 16)),
    ("super_resolution", (1, 3, 8, 8)),
])
def test_pallas_path_matches_lax_path(key, in_shape):
    """The same model through the L1 Pallas kernels and through lax.conv
    must agree — this pins the whole conv lowering (im2col order, padding,
    bias) to XLA's semantics."""
    init, forward, _ = MODELS[key]
    params = init(jax.random.PRNGKey(1), 0.25)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(in_shape, dtype=np.float32)) * 0.3
    y_kernel = forward(params, x, use_kernel=True)
    y_lax = forward(params, x, use_kernel=False)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_lax), rtol=2e-3, atol=2e-3
    )


def test_style_output_in_unit_interval():
    init, forward, _ = MODELS["style_transfer"]
    params = init(jax.random.PRNGKey(2), 0.25)
    x = jnp.ones((1, 3, 16, 16), jnp.float32) * 0.7
    y = forward(params, x, use_kernel=False)
    assert float(y.min()) >= 0.0 and float(y.max()) <= 1.0


def test_graph_node_lists_are_wellformed():
    for key, (init, _, graph_fn) in MODELS.items():
        hw = 16 if key != "super_resolution" else 8
        nodes = graph_fn(hw, 0.25)
        names = [n["name"] for n in nodes]
        assert len(names) == len(set(names)), f"{key}: duplicate node names"
        seen = set()
        for n in nodes:
            for inp in n["inputs"]:
                assert inp in seen, f"{key}: node {n['name']} references later node {inp}"
            seen.add(n["name"])
        assert nodes[0]["op"] == "input"
        assert nodes[-1]["op"] == "output"
        # Params cover every conv/dense/norm node in the graph.
        params = init(jax.random.PRNGKey(0), 0.25)
        for n in nodes:
            if n["op"] in ("conv2d", "dense"):
                assert f"{n['name']}.weight" in params, f"{key}: missing {n['name']}.weight"
            if n["op"] in ("batchnorm", "instancenorm"):
                assert f"{n['name']}.gamma" in params


def test_synthetic_data_shapes():
    x, y = data.app_batch("style", 2, 16)
    assert x.shape == (2, 3, 16, 16) and y.shape == (2, 3, 16, 16)
    x, y = data.app_batch("coloring", 2, 16)
    assert x.shape == (2, 1, 16, 16) and y.shape == (2, 3, 16, 16)
    x, y = data.app_batch("sr", 2, 8)
    assert x.shape == (2, 3, 8, 8) and y.shape == (2, 3, 32, 32)
    assert x.min() >= 0.0 and x.max() <= 1.0


def test_synthetic_data_deterministic():
    a, _ = data.app_batch("style", 1, 16, seed=5)
    b, _ = data.app_batch("style", 1, 16, seed=5)
    c, _ = data.app_batch("style", 1, 16, seed=6)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
