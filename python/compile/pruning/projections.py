"""Euclidean projections onto the structured constraint sets S_i (Eq. 1).

Each projection takes OIHW weights and returns the closest tensor whose
support satisfies the structure at the requested sparsity — the Z-update of
ADMM. Mirrors rust/src/pruning/scheme.rs::project_scheme (pytest asserts
the two agree through exported masks).
"""

import numpy as np

# The canonical 4-entry 3x3 pattern dictionary (PConv-style); flat kernel
# positions 0..8, centre = 4. Identical to rust PatternSet::pconv_3x3().
PCONV_PATTERNS = [
    (1, 3, 4, 5),
    (1, 4, 5, 7),
    (3, 4, 5, 7),
    (1, 3, 4, 7),
    (0, 1, 3, 4),
    (1, 2, 4, 5),
    (3, 4, 6, 7),
    (4, 5, 7, 8),
]


def project_column(w, sparsity):
    """Keep the strongest (1-sparsity) fraction of GEMM columns (same
    positions across all filters)."""
    w = np.asarray(w, dtype=np.float32)
    o = w.shape[0]
    cols = int(np.prod(w.shape[1:]))
    m = w.reshape(o, cols)
    norms = (m * m).sum(axis=0)
    keep_n = max(int(round(cols * (1.0 - sparsity))), 1)
    keep = np.sort(np.argsort(-norms)[:keep_n])
    out = np.zeros_like(m)
    out[:, keep] = m[:, keep]
    return out.reshape(w.shape), {"kind": "column", "keep": keep.tolist()}


def project_filter(w, sparsity):
    """Keep the strongest filters (whole rows)."""
    w = np.asarray(w, dtype=np.float32)
    o = w.shape[0]
    m = w.reshape(o, -1)
    norms = (m * m).sum(axis=1)
    keep_n = max(int(round(o * (1.0 - sparsity))), 1)
    keep = np.sort(np.argsort(-norms)[:keep_n])
    out = np.zeros_like(m)
    out[keep, :] = m[keep, :]
    return out.reshape(w.shape), {"kind": "filter", "keep": keep.tolist()}


def project_channel(w, sparsity):
    """Keep the strongest input channels."""
    w = np.asarray(w, dtype=np.float32)
    o, i = w.shape[0], w.shape[1]
    m = w.reshape(o, i, -1)
    norms = (m * m).sum(axis=(0, 2))
    keep_n = max(int(round(i * (1.0 - sparsity))), 1)
    keep = np.sort(np.argsort(-norms)[:keep_n])
    out = np.zeros_like(m)
    out[:, keep, :] = m[:, keep, :]
    return out.reshape(w.shape), {"kind": "channel", "keep": keep.tolist()}


def project_pattern(w, sparsity):
    """Pattern + connectivity projection for 3x3 kernels.

    Every surviving kernel keeps its best-matching 4-entry dictionary
    pattern; the weakest kernels are removed entirely (connectivity
    pruning) so overall density hits (1 - sparsity).
    """
    w = np.asarray(w, dtype=np.float32)
    o, i, kh, kw = w.shape
    assert (kh, kw) == (3, 3), "pattern pruning requires 3x3 kernels"
    ksz = 9
    kernels = w.reshape(o * i, ksz)
    conn_keep_frac = float(np.clip((1.0 - sparsity) * ksz / 4.0, 0.05, 1.0))
    keep_kernels = max(int(round(o * i * conn_keep_frac)), 1)
    norms = (kernels * kernels).sum(axis=1)
    kept = set(np.argsort(-norms)[:keep_kernels].tolist())

    pat_mat = np.zeros((len(PCONV_PATTERNS), ksz), dtype=np.float32)
    for pi, pat in enumerate(PCONV_PATTERNS):
        pat_mat[pi, list(pat)] = 1.0

    out = np.zeros_like(kernels)
    ids = np.full((o, i), 255, dtype=np.uint8)
    mags = np.abs(kernels) @ pat_mat.T  # [o*i, P]: retained magnitude per pattern
    best = np.argmax(mags, axis=1)
    for kidx in kept:
        pid = int(best[kidx])
        pat = list(PCONV_PATTERNS[pid])
        out[kidx, pat] = kernels[kidx, pat]
        ids[kidx // i, kidx % i] = pid
    return out.reshape(w.shape), {"kind": "pattern", "ids": ids.tolist()}


PROJECTIONS = {
    "column": project_column,
    "filter": project_filter,
    "channel": project_channel,
    "pattern": project_pattern,
}


def project(w, kind, sparsity):
    """Dispatch by scheme kind. Returns (projected weights, scheme meta)."""
    return PROJECTIONS[kind](w, sparsity)
