"""ADMM structured pruning (§2, Eq. 1).

The pruning problem  min f({W_i}) s.t. W_i ∈ S_i  is split via ADMM:

  W-step:  W ← argmin f(W) + (ρ/2)·Σ‖W_i − Z_i + U_i‖²   (SGD steps)
  Z-step:  Z_i ← Π_{S_i}(W_i + U_i)                        (projection)
  U-step:  U_i ← U_i + W_i − Z_i                           (dual ascent)

After convergence the *hard-prune* step fixes the support to Z's and
fine-tunes the surviving weights. `f` is task loss supplied by the caller
(train.py uses output-distillation against the dense model on synthetic
data — see DESIGN.md §2 substitutions).
"""

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.pruning.projections import project


@dataclass
class AdmmConfig:
    rho: float = 1e-1
    admm_iters: int = 6
    sgd_steps_per_iter: int = 20
    lr: float = 5e-3
    finetune_steps: int = 40
    log: List[dict] = field(default_factory=list)


def _masked(params, masks):
    return {k: v * masks[k] if k in masks else v for k, v in params.items()}


def admm_prune(
    loss_fn: Callable[[Dict[str, jnp.ndarray]], jnp.ndarray],
    params: Dict[str, jnp.ndarray],
    schemes: Dict[str, Tuple[str, float]],
    cfg: AdmmConfig,
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, np.ndarray], AdmmConfig]:
    """Run ADMM pruning.

    loss_fn: params -> scalar loss (the task objective f).
    params:  full parameter dict; only keys in `schemes` are constrained.
    schemes: weight key -> (scheme kind, sparsity).

    Returns (pruned params — exactly structured, masks, cfg with log).
    """
    grad_fn = jax.jit(jax.value_and_grad(lambda p: loss_fn(p)))

    # Initialise Z by projection, U at zero.
    z = {}
    u = {k: jnp.zeros_like(params[k]) for k in schemes}
    for k, (kind, sp) in schemes.items():
        zk, _ = project(np.asarray(params[k]), kind, sp)
        z[k] = jnp.asarray(zk)

    def admm_penalty(p):
        return sum(
            0.5 * cfg.rho * jnp.sum((p[k] - z[k] + u[k]) ** 2) for k in schemes
        )

    params = dict(params)
    for it in range(cfg.admm_iters):
        # W-step: SGD on f + rho/2 ||W - Z + U||^2.
        aug = jax.jit(
            jax.value_and_grad(lambda p: loss_fn(p) + admm_penalty(p))
        )
        for _ in range(cfg.sgd_steps_per_iter):
            val, g = aug(params)
            params = {k: v - cfg.lr * g[k] for k, v in params.items()}
        # Z-step: projection of W + U onto S.
        for k, (kind, sp) in schemes.items():
            zk, _ = project(np.asarray(params[k] + u[k]), kind, sp)
            z[k] = jnp.asarray(zk)
        # U-step.
        primal = 0.0
        for k in schemes:
            u[k] = u[k] + params[k] - z[k]
            primal += float(jnp.linalg.norm(params[k] - z[k]))
        task_loss, _ = grad_fn(params)
        cfg.log.append(
            {"iter": it, "task_loss": float(task_loss), "primal_residual": primal}
        )

    # Hard prune: adopt Z's support, fine-tune surviving weights under mask.
    masks = {k: np.asarray(z[k] != 0, dtype=np.float32) for k in schemes}
    params = {
        k: (v * masks[k] if k in masks else v) for k, v in params.items()
    }
    ft = jax.jit(jax.value_and_grad(lambda p: loss_fn(_masked(p, masks))))
    for _ in range(cfg.finetune_steps):
        val, g = ft(params)
        params = {k: v - cfg.lr * g[k] for k, v in params.items()}
    params = _masked(params, masks)
    final_loss = float(loss_fn(params))
    cfg.log.append({"iter": "final", "task_loss": final_loss, "primal_residual": 0.0})
    return params, masks, cfg
