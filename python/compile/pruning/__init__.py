"""Structured pruning: constraint sets, projections, ADMM (§2 of the paper)."""

from compile.pruning.admm import AdmmConfig, admm_prune
from compile.pruning.magnitude import magnitude_prune
from compile.pruning.projections import (
    PCONV_PATTERNS,
    project,
    project_channel,
    project_column,
    project_filter,
    project_pattern,
)

__all__ = [
    "AdmmConfig",
    "admm_prune",
    "magnitude_prune",
    "project",
    "project_column",
    "project_filter",
    "project_channel",
    "project_pattern",
    "PCONV_PATTERNS",
]
