"""One-shot magnitude structured pruning — the baseline ADMM is compared
against in the A1 experiment (project once, fine-tune under fixed mask)."""

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.pruning.projections import project


def magnitude_prune(
    loss_fn: Callable[[Dict[str, jnp.ndarray]], jnp.ndarray],
    params: Dict[str, jnp.ndarray],
    schemes: Dict[str, Tuple[str, float]],
    finetune_steps: int = 40,
    lr: float = 1e-2,
):
    """Project by magnitude once, then fine-tune surviving weights."""
    params = dict(params)
    masks = {}
    for k, (kind, sp) in schemes.items():
        pruned, _ = project(np.asarray(params[k]), kind, sp)
        masks[k] = np.asarray(pruned != 0, dtype=np.float32)
        params[k] = jnp.asarray(pruned)

    def masked(p):
        return {k: v * masks[k] if k in masks else v for k, v in p.items()}

    step = jax.jit(jax.value_and_grad(lambda p: loss_fn(masked(p))))
    for _ in range(finetune_steps):
        _, g = step(params)
        params = {k: v - lr * g[k] for k, v in params.items()}
    params = masked(params)
    return params, masks, float(loss_fn(params))
