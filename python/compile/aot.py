"""AOT pipeline: lower the JAX demo models to HLO **text** + export weights
and LR graphs for the Rust runtime. Runs once via `make artifacts`.

Interchange is HLO text (NOT `.serialize()`): jax ≥ 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md and gen_hlo.py).

Outputs in --out-dir (default ../artifacts):
    manifest.json                 index consumed by rust runtime::Manifest
    <app>.hlo.txt                 dense model, Pallas kernels inlined
    <app>_pruned.hlo.txt          ADMM-pruned weights baked in
    <app>.graph.json + weights/   LR graph for the native executor
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import data
from compile.export import export_graph
from compile.models import MODELS
from compile.pruning import project

APPS = {
    # app -> (model key, input builder, artifact hw, width)
    "style_transfer": ("style_transfer", lambda hw: (1, 3, hw, hw), 64, 0.25),
    "coloring": ("coloring", lambda hw: (1, 1, hw, hw), 64, 0.25),
    "super_resolution": ("super_resolution", lambda hw: (1, 3, hw, hw), 24, 0.25),
}

APP_SCHEME = {
    "style_transfer": ("column", 0.75),
    "coloring": ("pattern", 0.75),
    "super_resolution": ("pattern", 0.70),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: baked-in weights must survive the text
    # round trip (the default elides them as `constant({...})`, which the
    # Rust-side parser cannot reconstruct).
    return comp.as_hlo_text(True)


def prune_params(params, scheme_kind, sparsity):
    """Magnitude-project all prunable convs (AOT-time hard pruning; the
    full ADMM path lives in train.py — artifacts use the same projection
    the Rust side verifies)."""
    out = dict(params)
    stem = next(
        (f"{s}.weight" for s in ("enc1", "low1", "head") if f"{s}.weight" in params),
        None,
    )
    for k, v in params.items():
        if not k.endswith(".weight") or np.ndim(v) != 4 or k == stem:
            continue
        o, i, kh, kw = v.shape
        if scheme_kind == "pattern" and ((kh, kw) != (3, 3) or o <= 4):
            continue
        if scheme_kind != "pattern" and i * kh * kw < 32:
            continue
        pruned, _ = project(np.asarray(v), scheme_kind, sparsity)
        out[k] = jnp.asarray(pruned)
    return out


def lower_app(name, params, forward, in_shape, use_kernel=True):
    def fn(x):
        return (forward(params, x),)

    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--apps", default="all", help="comma list or 'all'")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument(
        "--skip-pruned", action="store_true", help="only emit dense artifacts"
    )
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    wanted = list(APPS) if args.apps == "all" else args.apps.split(",")

    models = []
    for app in wanted:
        key, shape_fn, hw, width = APPS[app]
        init, forward, graph_fn = MODELS[key]
        params = init(jax.random.PRNGKey(args.seed), width)
        in_shape = shape_fn(hw)

        # Smoke-run the forward (kernels included) before lowering.
        x = jnp.asarray(data.app_batch(app.split("_")[0] if app != "super_resolution" else "sr", 1, hw, seed=1)[0])
        y = forward(params, x)
        out_shape = list(np.shape(y))

        # Dense artifact.
        hlo = lower_app(app, params, forward, in_shape)
        hlo_name = f"{app}.hlo.txt"
        with open(os.path.join(out_dir, hlo_name), "w") as f:
            f.write(hlo)
        models.append(
            {
                "name": app,
                "variant": "dense",
                "hlo": hlo_name,
                "inputs": [list(in_shape)],
                "outputs": [out_shape],
            }
        )
        print(f"[aot] {app}: dense HLO {len(hlo)} chars, out={out_shape}")

        # Pruned artifact (projected weights baked in).
        if not args.skip_pruned:
            kind, sp = APP_SCHEME[app]
            pp = prune_params(params, kind, sp)
            hlo_p = lower_app(app, pp, forward, in_shape)
            hlo_p_name = f"{app}_pruned.hlo.txt"
            with open(os.path.join(out_dir, hlo_p_name), "w") as f:
                f.write(hlo_p)
            models.append(
                {
                    "name": app,
                    "variant": "pruned",
                    "hlo": hlo_p_name,
                    "inputs": [list(in_shape)],
                    "outputs": [out_shape],
                }
            )
            print(f"[aot] {app}: pruned ({kind}@{sp}) HLO {len(hlo_p)} chars")

        # LR graph + weights for the native executor (same weights!).
        nodes = graph_fn(hw, width)
        export_graph(out_dir, app, nodes, {k: np.asarray(v) for k, v in params.items()})
        print(f"[aot] {app}: exported LR graph + {len(params)} weight arrays")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"format": "prt-dnn-artifacts", "models": models}, f, indent=2)
    print(f"[aot] wrote manifest with {len(models)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
