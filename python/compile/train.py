"""Training + ADMM pruning driver (experiment A1).

Distillation objective: the *dense* model (randomly initialised, briefly
trained on the synthetic corpus) defines reference outputs; ADMM prunes
while holding those outputs — validating that ADMM converges to exactly
structured weights with a small loss delta, which is the paper's §2 claim
at reproduction scale (DESIGN.md §2).

Usage:
    python -m compile.train --app style --width 0.25 --hw 32
    python -m compile.train --all            # all three apps, log summary
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import data
from compile.models import MODELS
from compile.pruning import AdmmConfig, admm_prune, magnitude_prune

APP_KEY = {"style": "style_transfer", "coloring": "coloring", "sr": "super_resolution"}

# Per-app scheme kinds (paper §2: column for style, kernel/pattern for the
# other two) + sparsity targets matching rust AppSpec::for_app.
APP_SCHEME = {"style": ("column", 0.75), "coloring": ("pattern", 0.75), "sr": ("pattern", 0.70)}


def prunable_keys(params, kind):
    """Weight keys eligible for pruning (mirrors rust apps::variant)."""
    convs = [k for k in params if k.endswith(".weight") and params[k].ndim == 4]
    # First conv (stem) stays dense.
    order = ["enc1", "low1", "head"]
    stem = next((f"{s}.weight" for s in order if f"{s}.weight" in params), None)
    keys = []
    for k in convs:
        if k == stem:
            continue
        o, i, kh, kw = params[k].shape
        if kind == "pattern":
            if (kh, kw) == (3, 3) and o > 4:
                keys.append(k)
        else:
            if i * kh * kw >= 32:
                keys.append(k)
    return keys


def run_app(app, width=0.25, hw=32, seed=0, quick=False):
    key = APP_KEY[app]
    init, forward, _ = MODELS[key]
    rng = jax.random.PRNGKey(seed)
    params = init(rng, width)
    kind, sparsity = APP_SCHEME[app]

    x_np, y_np = data.app_batch(app, 4, hw, seed=seed)
    x, y = jnp.asarray(x_np), jnp.asarray(y_np)

    fwd = (lambda p, xx: forward(p, xx, use_kernel=False))

    # Brief dense pre-training toward the task target.
    def task_loss(p):
        return jnp.mean((fwd(p, x) - y) ** 2)

    step = jax.jit(jax.value_and_grad(task_loss))
    params = dict(params)
    pre_steps = 10 if quick else 60
    # SR regresses 4x-resolution targets through a residual skip — larger
    # gradients, so it needs a gentler step.
    pre_lr = 2e-3 if app == "sr" else 2e-2
    for _ in range(pre_steps):
        _, g = step(params)
        params = {k: v - pre_lr * g[k] for k, v in params.items()}
    dense_loss = float(task_loss(params))

    # Distillation target = dense model outputs.
    ref = fwd(params, x)

    def distill_loss(p):
        return jnp.mean((fwd(p, x) - ref) ** 2)

    schemes = {k: (kind, sparsity) for k in prunable_keys(params, kind)}
    cfg = AdmmConfig(
        lr=1e-3 if app == "sr" else 5e-3,
        admm_iters=2 if quick else 5,
        sgd_steps_per_iter=5 if quick else 15,
        finetune_steps=10 if quick else 40,
    )
    pruned, masks, cfg = admm_prune(distill_loss, params, schemes, cfg)
    admm_loss = float(distill_loss(pruned))

    # Magnitude baseline for comparison.
    mag, _, mag_loss = magnitude_prune(
        distill_loss, params, schemes,
        finetune_steps=10 if quick else 40,
        lr=1e-3 if app == "sr" else 1e-2,
    )

    density = float(
        np.mean([float(np.mean(masks[k])) for k in schemes]) if schemes else 1.0
    )
    report = {
        "app": app,
        "scheme": kind,
        "target_sparsity": sparsity,
        "layers_pruned": len(schemes),
        "achieved_density": density,
        "dense_task_loss": dense_loss,
        "admm_distill_loss": admm_loss,
        "magnitude_distill_loss": mag_loss,
        "admm_log": cfg.log,
    }
    return pruned, masks, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", choices=["style", "coloring", "sr"], default="style")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON report here")
    args = ap.parse_args()

    apps = ["style", "coloring", "sr"] if args.all else [args.app]
    reports = []
    for app in apps:
        _, _, report = run_app(app, width=args.width, hw=args.hw, quick=args.quick)
        reports.append(report)
        print(
            f"[{app}] scheme={report['scheme']} layers={report['layers_pruned']} "
            f"density={report['achieved_density']:.3f} "
            f"admm_loss={report['admm_distill_loss']:.5f} "
            f"magnitude_loss={report['magnitude_distill_loss']:.5f}"
        )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
