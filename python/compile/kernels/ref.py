"""Pure-jnp reference oracles for the Pallas kernels.

Everything here is the *semantic definition*; `column_gemm.py` /
`pattern_conv.py` must match these to float tolerance (pytest enforces it
with hypothesis sweeps). The oracles are also used by the model layer when
a conv is too small to be worth a kernel launch.
"""

import jax
import jax.numpy as jnp
import numpy as np


def matmul_ref(a, b):
    """C[M,N] = A[M,K] @ B[K,N], f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def column_pruned_matmul_ref(w_packed, keep, x):
    """Column-pruned GEMM: W stored packed over kept columns.

    w_packed: [M, Kp] — dense values of the kept columns.
    keep:     [Kp] int32 — kept column (GEMM-K) indices, sorted.
    x:        [K, N] — the full dense right-hand side (im2col patches).

    Equivalent to (W_full @ x) where W_full scatters w_packed into zeros.
    """
    x_packed = x[keep, :]  # gather kept rows: the compiler transform
    return matmul_ref(w_packed, x_packed)


def pattern_grouped_matmul_ref(groups, x, out_rows):
    """Reorder-grouped sparse GEMM (pattern pruning after compaction).

    groups: list of (rows[g_m] int32, cols[g_k] int32, vals[g_m, g_k] f32).
    x:      [K, N] dense rhs.
    out_rows: M of the output.

    Each group's rows share one column support; its inner product is dense
    over the compacted columns (the paper's matrix-reorder execution).
    """
    n = x.shape[1]
    out = jnp.zeros((out_rows, n), dtype=jnp.float32)
    for rows, cols, vals in groups:
        part = matmul_ref(jnp.asarray(vals), x[np.asarray(cols), :])
        out = out.at[np.asarray(rows), :].set(part)
    return out


def im2col_ref(x, kh, kw, stride, pad, pad_mode="zeros"):
    """Patch matrix [C*kh*kw, OH*OW] of a single CHW image.

    Row order matches the Rust side: row index = (c*kh + r)*kw + s.
    """
    c, h, w = x.shape
    if pad > 0:
        mode = "reflect" if pad_mode == "reflect" else "constant"
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)), mode=mode)
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    rows = []
    for ci in range(c):
        for r in range(kh):
            for s in range(kw):
                patch = jax.lax.dynamic_slice(
                    x,
                    (ci, r, s),
                    (1, (oh - 1) * stride + 1, (ow - 1) * stride + 1),
                )[0, ::stride, ::stride]
                rows.append(patch.reshape(-1))
    return jnp.stack(rows, axis=0), (oh, ow)


def conv2d_ref(x, w, bias=None, stride=1, pad=0, pad_mode="zeros"):
    """NCHW conv via im2col + matmul (the conv oracle).

    x: [N,C,H,W], w: [O,I,kh,kw].
    """
    n = x.shape[0]
    o, i, kh, kw = w.shape
    wm = w.reshape(o, i * kh * kw)
    outs = []
    for s in range(n):
        patches, (oh, ow) = im2col_ref(x[s], kh, kw, stride, pad, pad_mode)
        y = matmul_ref(wm, patches).reshape(o, oh, ow)
        outs.append(y)
    y = jnp.stack(outs, axis=0)
    if bias is not None:
        y = y + bias.reshape(1, -1, 1, 1)
    return y
