"""Pallas GEMM kernels: the compute hot-spot of every conv in the stack.

HARDWARE ADAPTATION (DESIGN.md §3): the paper's mobile-GPU insight is that
*structured* pruning lets the inner loop stay dense. On TPU that maps to:
gather the surviving im2col rows once (HBM→VMEM data movement expressed at
the XLA level), then run a **dense MXU matmul** over the reduced K. The
Pallas kernel is that dense tile matmul; `column_pruned_matmul` composes
gather + kernel.

VMEM / MXU accounting (per kernel instance, f32):
  A tile [bm, K], B tile [K, bn], C tile [bm, bn]
  VMEM = 4·(bm·K + K·bn + bm·bn) bytes; with bm=bn=128 and K ≤ 4608
  (the largest layer: 512·3·3) that is ≤ 4.8 MB — well under the ~16 MB
  VMEM budget, so no K-loop is needed at these model sizes.
  MXU: jnp.dot on [128,K]x[K,128] f32 tiles drives the 128×128 systolic
  array at full occupancy for K ≥ 128 (smaller K pads — documented
  inefficiency for the 1×1-conv layers).

interpret=True everywhere: the CPU-only image cannot execute Mosaic
custom-calls; structure is validated here, MXU efficiency is estimated
analytically (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-shaped.
BM = 128
BN = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One [bm, K] x [K, bn] -> [bm, bn] tile product on the MXU."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul_pallas(a, b, bm: int = BM, bn: int = BN):
    """C[M,N] = A[M,K] @ B[K,N] via a Pallas tile kernel.

    Inputs are zero-padded to tile multiples; the pad contributes zeros to
    the products and is sliced off the output.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul_pallas: K mismatch {k} vs {k2}"
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    ap = _pad_to(a.astype(jnp.float32), 0, bm)
    bp = _pad_to(b.astype(jnp.float32), 1, bn)
    mp, np_ = ap.shape[0], bp.shape[1]

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU-only image; see module docstring
    )(ap, bp)
    return out[:m, :n]


def column_pruned_matmul(w_packed, keep, x, bm: int = BM, bn: int = BN):
    """Column-pruned GEMM (the style-transfer hot path).

    w_packed: [M, Kp] packed kept-column weights.
    keep:     [Kp] int32 kept GEMM-K indices.
    x:        [K, N] full rhs (im2col patch matrix).

    The gather `x[keep]` is the HBM→VMEM compaction; the matmul runs dense
    over Kp — compute drops proportionally to the pruning rate with *zero*
    per-element index overhead in the inner loop.
    """
    x_packed = jnp.take(x, keep, axis=0)
    return matmul_pallas(w_packed, x_packed, bm=bm, bn=bn)
