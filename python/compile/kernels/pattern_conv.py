"""Pattern-pruned (reorder-grouped) GEMM — the coloring / SR hot path.

Build-time the matrix-reorder transform (mirroring rust/src/reorder/) groups
filters by identical pattern signature and compacts each group's columns.
Run-time, each group is a *dense* [g_m, g_k] × [g_k, N] product — exactly
the MXU-friendly shape. The group loop is unrolled at trace time (group
structure is static after pruning), so the whole layer lowers into a short
sequence of Pallas tile matmuls + scatters.

VMEM: per group 4·(g_m·g_k + g_k·N_tile + g_m·N_tile) bytes; pattern
pruning yields ≤ 8 signatures per layer in practice, each far smaller than
the dense layer, so the working set shrinks vs the dense kernel.
"""

import jax.numpy as jnp
import numpy as np

from compile.kernels.column_gemm import matmul_pallas


def build_groups(w_matrix):
    """Group rows of a dense-with-zeros weight matrix by column support.

    Returns a list of (rows int32[g_m], cols int32[g_k], vals f32[g_m,g_k]).
    Build-time only (numpy). Mirrors rust/src/reorder/plan.rs.
    """
    w = np.asarray(w_matrix)
    sigs = {}
    for r in range(w.shape[0]):
        support = tuple(np.nonzero(w[r])[0].tolist())
        if not support:
            continue
        sigs.setdefault(support, []).append(r)
    groups = []
    for support, rows in sorted(sigs.items()):
        cols = np.array(support, dtype=np.int32)
        rows = np.array(rows, dtype=np.int32)
        vals = w[rows[:, None], cols[None, :]].astype(np.float32)
        groups.append((rows, cols, vals))
    return groups


def pattern_grouped_matmul(groups, x, out_rows):
    """Execute reorder groups against rhs x: returns [out_rows, N].

    groups: output of `build_groups` (static python structure).
    x:      [K, N] jnp array.
    """
    n = x.shape[1]
    out = jnp.zeros((out_rows, n), dtype=jnp.float32)
    for rows, cols, vals in groups:
        x_packed = jnp.take(x, jnp.asarray(cols), axis=0)  # [g_k, N]
        part = matmul_pallas(jnp.asarray(vals), x_packed)  # [g_m, N]
        out = out.at[jnp.asarray(rows), :].set(part)
    return out
