"""Layer-1 Pallas kernels + pure-jnp oracles."""

from compile.kernels.column_gemm import column_pruned_matmul, matmul_pallas
from compile.kernels.pattern_conv import build_groups, pattern_grouped_matmul

__all__ = [
    "matmul_pallas",
    "column_pruned_matmul",
    "pattern_grouped_matmul",
    "build_groups",
]
