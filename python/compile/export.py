"""Export JAX model params + topology to the Rust LR-graph format
(`<name>.graph.json` + `.npy` weights) — rust/src/dsl/io.rs is the reader."""

import json
import os

import numpy as np


def write_npy(path, arr):
    np.save(path, np.asarray(arr, dtype=np.float32), allow_pickle=False)


def export_graph(out_dir, name, nodes, params):
    """Write `<out_dir>/<name>.graph.json` + `<name>.weights/*.npy`.

    nodes: list of node dicts (the `*_graph` functions in models/).
    params: dict of weight arrays keyed `node.slot`.
    """
    os.makedirs(out_dir, exist_ok=True)
    wdir = os.path.join(out_dir, f"{name}.weights")
    os.makedirs(wdir, exist_ok=True)
    param_index = {}
    for key in sorted(params):
        fname = f"{name}.weights/{key}.npy"
        write_npy(os.path.join(out_dir, fname), params[key])
        param_index[key] = fname
    doc = {
        "format": "prt-dnn-graph",
        "version": 1,
        "name": name,
        "nodes": nodes,
        "params": param_index,
    }
    json_path = os.path.join(out_dir, f"{name}.graph.json")
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2)
    return json_path
