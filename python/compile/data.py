"""Procedural tiny corpora — the COCO / Places / DIV2K stand-ins
(DESIGN.md §2). Deterministic per seed; numpy only."""

import numpy as np


def synth_photo(hw, seed):
    """One synthetic photo [3, hw, hw] in [0,1]: sky gradient + textured
    ground + blobs (matches rust/src/image/synth.rs in spirit)."""
    rng = np.random.default_rng(seed)
    img = np.zeros((3, hw, hw), dtype=np.float32)
    horizon = int(hw * rng.uniform(0.35, 0.65))
    sky = rng.uniform(0.4, 1.0, size=3)
    ground = rng.uniform(0.15, 0.7, size=3)
    yy = np.arange(hw).reshape(-1, 1) / max(hw - 1, 1)
    for c in range(3):
        img[c, :horizon, :] = sky[c] * (1.0 - 0.3 * yy[:horizon])
        noise = rng.random((hw - horizon, hw)).astype(np.float32)
        img[c, horizon:, :] = ground[c] * (0.7 + 0.5 * noise)
    for _ in range(rng.integers(2, 6)):
        cx, cy = rng.integers(0, hw, size=2)
        r = rng.uniform(0.08, 0.2) * hw
        color = rng.random(3).astype(np.float32)
        y, x = np.ogrid[:hw, :hw]
        d2 = (x - cx) ** 2 + (y - cy) ** 2
        a = np.clip(1.0 - d2 / (r * r), 0.0, 1.0).astype(np.float32)
        for c in range(3):
            img[c] = img[c] * (1 - a) + color[c] * a
    return np.clip(img, 0.0, 1.0)


def batch_photos(n, hw, seed):
    """[n, 3, hw, hw] batch of synthetic photos."""
    return np.stack([synth_photo(hw, seed * 1000 + i) for i in range(n)])


def grayscale(batch):
    """RGB batch -> luma batch [n, 1, h, w]."""
    r, g, b = batch[:, 0:1], batch[:, 1:2], batch[:, 2:3]
    return 0.299 * r + 0.587 * g + 0.114 * b


def downsample(batch, factor):
    """Box-filter downsample for SR pairs."""
    n, c, h, w = batch.shape
    return batch.reshape(n, c, h // factor, factor, w // factor, factor).mean(
        axis=(3, 5)
    )


def app_batch(app, n, hw, seed=0):
    """(input, target) training pair for an app at the given resolution.

    style: identity-ish target (the dense model's own output is the real
           distillation target; here input==reference photo)
    coloring: gray -> RGB
    sr: low-res -> high-res (hw is the LOW resolution; target is 4x)
    """
    if app in ("style", "style_transfer"):
        x = batch_photos(n, hw, seed)
        return x.astype(np.float32), x.astype(np.float32)
    if app == "coloring":
        y = batch_photos(n, hw, seed)
        return grayscale(y).astype(np.float32), y.astype(np.float32)
    if app in ("sr", "super_resolution"):
        hi = batch_photos(n, hw * 4, seed)
        return downsample(hi, 4).astype(np.float32), hi.astype(np.float32)
    raise ValueError(f"unknown app {app}")
