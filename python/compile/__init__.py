"""prt-dnn build-time python package: L2 JAX models + ADMM structured
pruning + L1 Pallas kernels + the AOT export pipeline.

Never imported at inference time — `make artifacts` runs it once; the Rust
binary consumes the outputs (HLO text, .npy weights, LR-graph JSON).
"""
