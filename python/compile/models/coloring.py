"""DNN coloring network (Iizuka et al. 2016 global+local fusion, reduced).

Mirrors rust/src/apps/builders.rs::build_coloring.
"""

import jax
import jax.numpy as jnp

from compile.models.blocks import (
    batch_norm,
    ch,
    conv2d,
    global_avg_pool,
    he_init,
    init_conv,
    init_norm,
    upsample_nearest,
)


def init_coloring(rng, width=0.25):
    c1, c2, c3 = ch(16, width), ch(32, width), ch(48, width)
    params = {}
    keys = jax.random.split(rng, 16)
    init_conv(params, keys[0], "low1", c1, 1, 3)
    init_norm(params, "low1_bn", c1, "bn")
    init_conv(params, keys[1], "low2", c2, c1, 3)
    init_norm(params, "low2_bn", c2, "bn")
    init_conv(params, keys[2], "low3", c3, c2, 3)
    init_norm(params, "low3_bn", c3, "bn")
    init_conv(params, keys[3], "mid1", c3, c3, 3)
    init_norm(params, "mid1_bn", c3, "bn")
    init_conv(params, keys[4], "glob1", c3, c3, 3)
    init_norm(params, "glob1_bn", c3, "bn")
    init_conv(params, keys[5], "glob2", c3, c3, 3)
    init_norm(params, "glob2_bn", c3, "bn")
    params["glob_fc.weight"] = he_init(keys[6], (c3, c3))
    params["glob_fc.bias"] = jnp.zeros((c3,), jnp.float32)
    init_conv(params, keys[7], "fuse1", c2, 2 * c3, 1)
    init_conv(params, keys[8], "col1", c2, c2, 3)
    init_conv(params, keys[9], "col2", c1, c2, 3)
    init_conv(params, keys[10], "col3", 3, c1, 3)
    return params


def coloring_forward(params, x, use_kernel=True):
    """x: [N, 1, H, W] grayscale -> RGB [N, 3, H, W]."""
    k = dict(use_kernel=use_kernel)
    h = conv2d(params, "low1", x, stride=2, **k)
    h = jax.nn.relu(batch_norm(params, "low1_bn", h))
    h = conv2d(params, "low2", h, **k)
    h = jax.nn.relu(batch_norm(params, "low2_bn", h))
    h = conv2d(params, "low3", h, stride=2, **k)
    low = jax.nn.relu(batch_norm(params, "low3_bn", h))

    mid = conv2d(params, "mid1", low, **k)
    mid = jax.nn.relu(batch_norm(params, "mid1_bn", mid))

    g = conv2d(params, "glob1", low, stride=2, **k)
    g = jax.nn.relu(batch_norm(params, "glob1_bn", g))
    g = conv2d(params, "glob2", g, stride=2, **k)
    g = jax.nn.relu(batch_norm(params, "glob2_bn", g))
    g = global_avg_pool(g)  # [N, C]
    g = jax.nn.relu(g @ params["glob_fc.weight"].T + params["glob_fc.bias"])

    # Broadcast global features over the mid spatial grid + concat.
    n, c = g.shape
    _, _, mh, mw = mid.shape
    gb = jnp.broadcast_to(g.reshape(n, c, 1, 1), (n, c, mh, mw))
    fused = jnp.concatenate([mid, gb], axis=1)
    h = jax.nn.relu(conv2d(params, "fuse1", fused, pad=0, **k))

    h = jax.nn.relu(conv2d(params, "col1", h, **k))
    h = upsample_nearest(h, 2)
    h = jax.nn.relu(conv2d(params, "col2", h, **k))
    h = upsample_nearest(h, 2)
    h = conv2d(params, "col3", h, **k)
    return jax.nn.sigmoid(h)


def coloring_graph(hw, width=0.25):
    c1, c2, c3 = ch(16, width), ch(32, width), ch(48, width)

    def conv_node(name, inputs, out_c, in_c, kk, stride=1, pad=None):
        return {
            "name": name,
            "op": "conv2d",
            "inputs": inputs,
            "attrs": {
                "out_c": out_c,
                "in_c": in_c,
                "kh": kk,
                "kw": kk,
                "stride": stride,
                "pad": kk // 2 if pad is None else pad,
                "pad_mode": "zeros",
                "fused_act": "identity",
            },
        }

    def bn(name, inputs, c):
        return {
            "name": name,
            "op": "batchnorm",
            "inputs": inputs,
            "attrs": {"c": c, "eps": 1e-5},
        }

    def act(name, inputs, fn="relu"):
        return {"name": name, "op": "act", "inputs": inputs, "attrs": {"fn": fn}}

    nodes = [
        {"name": "x", "op": "input", "inputs": [], "attrs": {"shape": [1, 1, hw, hw]}},
        conv_node("low1", ["x"], c1, 1, 3, 2),
        bn("low1_bn", ["low1"], c1),
        act("low1_relu", ["low1_bn"]),
        conv_node("low2", ["low1_relu"], c2, c1, 3),
        bn("low2_bn", ["low2"], c2),
        act("low2_relu", ["low2_bn"]),
        conv_node("low3", ["low2_relu"], c3, c2, 3, 2),
        bn("low3_bn", ["low3"], c3),
        act("low3_relu", ["low3_bn"]),
        conv_node("mid1", ["low3_relu"], c3, c3, 3),
        bn("mid1_bn", ["mid1"], c3),
        act("mid1_relu", ["mid1_bn"]),
        conv_node("glob1", ["low3_relu"], c3, c3, 3, 2),
        bn("glob1_bn", ["glob1"], c3),
        act("glob1_relu", ["glob1_bn"]),
        conv_node("glob2", ["glob1_relu"], c3, c3, 3, 2),
        bn("glob2_bn", ["glob2"], c3),
        act("glob2_relu", ["glob2_bn"]),
        {"name": "gap", "op": "gap", "inputs": ["glob2_relu"], "attrs": {}},
        {
            "name": "glob_fc",
            "op": "dense",
            "inputs": ["gap"],
            "attrs": {"out_f": c3, "in_f": c3, "fused_act": "relu"},
        },
        {
            "name": "fuse_broadcast",
            "op": "broadcast",
            "inputs": ["glob_fc", "mid1_relu"],
            "attrs": {},
        },
        {
            "name": "fuse_concat",
            "op": "concat",
            "inputs": ["mid1_relu", "fuse_broadcast"],
            "attrs": {},
        },
        conv_node("fuse1", ["fuse_concat"], c2, 2 * c3, 1),
        act("fuse1_relu", ["fuse1"]),
        conv_node("col1", ["fuse1_relu"], c2, c2, 3),
        act("col1_relu", ["col1"]),
        {"name": "col_up1", "op": "upsample", "inputs": ["col1_relu"], "attrs": {"factor": 2}},
        conv_node("col2", ["col_up1"], c1, c2, 3),
        act("col2_relu", ["col2"]),
        {"name": "col_up2", "op": "upsample", "inputs": ["col2_relu"], "attrs": {"factor": 2}},
        conv_node("col3", ["col_up2"], 3, c1, 3),
        act("out_sigmoid", ["col3"], "sigmoid"),
        {"name": "out", "op": "output", "inputs": ["out_sigmoid"], "attrs": {}},
    ]
    return nodes
