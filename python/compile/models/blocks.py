"""Shared building blocks: conv (via the Pallas GEMM), norms, resampling.

Parameter dictionaries are keyed exactly like the Rust LR graphs
(`"enc1.weight"`, `"enc1_in.gamma"`, …) so `export.py` can emit a graph
JSON the Rust DSL loads verbatim, and artifact outputs are directly
comparable against the native executor on the same weights.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.column_gemm import matmul_pallas
from compile.kernels.ref import im2col_ref


def he_init(rng, shape):
    fan_in = int(np.prod(shape[1:])) if len(shape) > 1 else shape[0]
    std = float(np.sqrt(2.0 / max(fan_in, 1)))
    return jax.random.normal(rng, shape, dtype=jnp.float32) * std


def init_conv(params, rng, name, out_c, in_c, k):
    r1, _ = jax.random.split(rng)
    params[f"{name}.weight"] = he_init(r1, (out_c, in_c, k, k))
    params[f"{name}.bias"] = jnp.zeros((out_c,), jnp.float32)


def init_norm(params, name, c, kind="in"):
    params[f"{name}.gamma"] = jnp.ones((c,), jnp.float32)
    params[f"{name}.beta"] = jnp.zeros((c,), jnp.float32)
    if kind == "bn":
        params[f"{name}.mean"] = jnp.zeros((c,), jnp.float32)
        params[f"{name}.var"] = jnp.ones((c,), jnp.float32)


def conv2d(params, name, x, stride=1, pad=None, pad_mode="zeros", use_kernel=True):
    """NCHW conv through im2col + the Pallas GEMM (the L1 hot path).

    With `use_kernel=False` falls back to lax.conv (used for gradient-time
    training where interpret-mode pallas is slow).
    """
    w = params[f"{name}.weight"]
    b = params.get(f"{name}.bias")
    o, i, kh, kw = w.shape
    if pad is None:
        pad = kh // 2
    if not use_kernel:
        xp = x
        if pad > 0:
            mode = "reflect" if pad_mode == "reflect" else "constant"
            xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)), mode=mode)
        y = jax.lax.conv_general_dilated(
            xp, w, (stride, stride), "VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
    else:
        wm = w.reshape(o, i * kh * kw)
        outs = []
        for s in range(x.shape[0]):
            patches, (oh, ow) = im2col_ref(x[s], kh, kw, stride, pad, pad_mode)
            outs.append(matmul_pallas(wm, patches).reshape(o, oh, ow))
        y = jnp.stack(outs, axis=0)
    if b is not None:
        y = y + b.reshape(1, -1, 1, 1)
    return y


def instance_norm(params, name, x, eps=1e-5):
    mean = x.mean(axis=(2, 3), keepdims=True)
    var = x.var(axis=(2, 3), keepdims=True)
    g = params[f"{name}.gamma"].reshape(1, -1, 1, 1)
    b = params[f"{name}.beta"].reshape(1, -1, 1, 1)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def batch_norm(params, name, x, eps=1e-5):
    g = params[f"{name}.gamma"].reshape(1, -1, 1, 1)
    b = params[f"{name}.beta"].reshape(1, -1, 1, 1)
    m = params[f"{name}.mean"].reshape(1, -1, 1, 1)
    v = params[f"{name}.var"].reshape(1, -1, 1, 1)
    return (x - m) / jnp.sqrt(v + eps) * g + b


def upsample_nearest(x, factor):
    return jnp.repeat(jnp.repeat(x, factor, axis=2), factor, axis=3)


def pixel_shuffle(x, r):
    """[N, C·r², H, W] -> [N, C, H·r, W·r]; channel (c·r²+dy·r+dx) maps to
    output (c, y·r+dy, x·r+dx) — identical to the Rust kernel."""
    n, cin, h, w = x.shape
    c = cin // (r * r)
    x = x.reshape(n, c, r, r, h, w)
    x = x.transpose(0, 1, 4, 2, 5, 3)  # n, c, h, dy, w, dx
    return x.reshape(n, c, h * r, w * r)


def global_avg_pool(x):
    return x.mean(axis=(2, 3))  # [N, C]


def ch(base, width):
    return max(int(round(base * width)), 2)
