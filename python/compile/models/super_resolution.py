"""Super resolution: WDSR-style wide-activation residual net (Yu et al. 2018).

Mirrors rust/src/apps/builders.rs::build_sr.
"""

import jax
import jax.numpy as jnp

from compile.models.blocks import (
    ch,
    conv2d,
    init_conv,
    pixel_shuffle,
    upsample_nearest,
)


def init_sr(rng, width=0.25, scale=4):
    c = ch(24, width)
    wide = c * 2
    params = {}
    keys = jax.random.split(rng, 10)
    init_conv(params, keys[0], "head", c, 3, 3)
    for b in range(3):
        init_conv(params, keys[1 + 2 * b], f"blk{b}_expand", wide, c, 3)
        init_conv(params, keys[2 + 2 * b], f"blk{b}_reduce", c, wide, 3)
    init_conv(params, keys[7], "tail", 3 * scale * scale, c, 3)
    return params


def sr_forward(params, x, scale=4, use_kernel=True):
    """x: [N, 3, h, w] -> [N, 3, h·scale, w·scale]."""
    k = dict(use_kernel=use_kernel)
    h = conv2d(params, "head", x, **k)
    for b in range(3):
        r = jax.nn.relu(conv2d(params, f"blk{b}_expand", h, **k))
        r = conv2d(params, f"blk{b}_reduce", r, **k)
        h = r + h
    t = conv2d(params, "tail", h, **k)
    up = pixel_shuffle(t, scale)
    skip = upsample_nearest(x, scale)
    return up + skip


def sr_graph(hw, width=0.25, scale=4):
    c = ch(24, width)
    wide = c * 2

    def conv_node(name, inputs, out_c, in_c, kk, stride=1):
        return {
            "name": name,
            "op": "conv2d",
            "inputs": inputs,
            "attrs": {
                "out_c": out_c,
                "in_c": in_c,
                "kh": kk,
                "kw": kk,
                "stride": stride,
                "pad": kk // 2,
                "pad_mode": "zeros",
                "fused_act": "identity",
            },
        }

    def act(name, inputs, fn="relu"):
        return {"name": name, "op": "act", "inputs": inputs, "attrs": {"fn": fn}}

    nodes = [
        {"name": "x", "op": "input", "inputs": [], "attrs": {"shape": [1, 3, hw, hw]}},
        conv_node("head", ["x"], c, 3, 3),
    ]
    prev = "head"
    for b in range(3):
        nodes += [
            conv_node(f"blk{b}_expand", [prev], wide, c, 3),
            act(f"blk{b}_relu", [f"blk{b}_expand"]),
            conv_node(f"blk{b}_reduce", [f"blk{b}_relu"], c, wide, 3),
            {
                "name": f"blk{b}_add",
                "op": "add",
                "inputs": [f"blk{b}_reduce", prev],
                "attrs": {},
            },
        ]
        prev = f"blk{b}_add"
    nodes += [
        conv_node("tail", [prev], 3 * scale * scale, c, 3),
        {
            "name": "pixelshuffle",
            "op": "pixelshuffle",
            "inputs": ["tail"],
            "attrs": {"factor": scale},
        },
        {"name": "skip_up", "op": "upsample", "inputs": ["x"], "attrs": {"factor": scale}},
        {"name": "skip_add", "op": "add", "inputs": ["pixelshuffle", "skip_up"], "attrs": {}},
        {"name": "out", "op": "output", "inputs": ["skip_add"], "attrs": {}},
    ]
    return nodes
