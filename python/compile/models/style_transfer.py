"""Style transfer generative network (MSG-Net-style; Zhang & Dana 2017).

Mirrors rust/src/apps/builders.rs::build_style exactly — same node names,
topology, and attribute values — so exported graphs load in the Rust DSL
and PJRT artifacts are numerically comparable with the native executor.
"""

import jax
import jax.numpy as jnp

from compile.models.blocks import (
    ch,
    conv2d,
    init_conv,
    init_norm,
    instance_norm,
    upsample_nearest,
)


def init_style(rng, width=0.25):
    c1, c2, c3 = ch(16, width), ch(32, width), ch(64, width)
    params = {}
    keys = jax.random.split(rng, 16)
    init_conv(params, keys[0], "enc1", c1, 3, 9)
    init_norm(params, "enc1_in", c1)
    init_conv(params, keys[1], "enc2", c2, c1, 3)
    init_norm(params, "enc2_in", c2)
    init_conv(params, keys[2], "enc3", c3, c2, 3)
    init_norm(params, "enc3_in", c3)
    for b in range(3):
        init_conv(params, keys[3 + 2 * b], f"res{b}_c1", c3, c3, 3)
        init_norm(params, f"res{b}_in1", c3)
        init_conv(params, keys[4 + 2 * b], f"res{b}_c2", c3, c3, 3)
        init_norm(params, f"res{b}_in2", c3)
    init_conv(params, keys[10], "dec1", c2, c3, 3)
    init_norm(params, "dec1_in", c2)
    init_conv(params, keys[11], "dec2", c1, c2, 3)
    init_norm(params, "dec2_in", c1)
    init_conv(params, keys[12], "dec3", 3, c1, 9)
    return params


def style_forward(params, x, use_kernel=True):
    """x: [N, 3, H, W] in [0,1] -> stylized [N, 3, H, W]."""
    k = dict(use_kernel=use_kernel, pad_mode="reflect")
    h = conv2d(params, "enc1", x, **k)
    h = jax.nn.relu(instance_norm(params, "enc1_in", h))
    h = conv2d(params, "enc2", h, stride=2, **k)
    h = jax.nn.relu(instance_norm(params, "enc2_in", h))
    h = conv2d(params, "enc3", h, stride=2, **k)
    h = jax.nn.relu(instance_norm(params, "enc3_in", h))
    for b in range(3):
        r = conv2d(params, f"res{b}_c1", h, **k)
        r = jax.nn.relu(instance_norm(params, f"res{b}_in1", r))
        r = conv2d(params, f"res{b}_c2", r, **k)
        r = instance_norm(params, f"res{b}_in2", r)
        h = r + h
    h = upsample_nearest(h, 2)
    h = conv2d(params, "dec1", h, **k)
    h = jax.nn.relu(instance_norm(params, "dec1_in", h))
    h = upsample_nearest(h, 2)
    h = conv2d(params, "dec2", h, **k)
    h = jax.nn.relu(instance_norm(params, "dec2_in", h))
    h = conv2d(params, "dec3", h, **k)
    return jax.nn.sigmoid(h)


def style_graph(hw, width=0.25):
    """LR-graph node list in the rust dsl::io JSON schema."""
    c1, c2, c3 = ch(16, width), ch(32, width), ch(64, width)

    def conv_node(name, inputs, out_c, in_c, kk, stride=1):
        return {
            "name": name,
            "op": "conv2d",
            "inputs": inputs,
            "attrs": {
                "out_c": out_c,
                "in_c": in_c,
                "kh": kk,
                "kw": kk,
                "stride": stride,
                "pad": kk // 2,
                "pad_mode": "reflect",
                "fused_act": "identity",
            },
        }

    def in_node(name, inputs, c):
        return {
            "name": name,
            "op": "instancenorm",
            "inputs": inputs,
            "attrs": {"c": c, "eps": 1e-5},
        }

    def act(name, inputs, fn="relu"):
        return {"name": name, "op": "act", "inputs": inputs, "attrs": {"fn": fn}}

    nodes = [
        {"name": "x", "op": "input", "inputs": [], "attrs": {"shape": [1, 3, hw, hw]}},
        conv_node("enc1", ["x"], c1, 3, 9),
        in_node("enc1_in", ["enc1"], c1),
        act("enc1_relu", ["enc1_in"]),
        conv_node("enc2", ["enc1_relu"], c2, c1, 3, 2),
        in_node("enc2_in", ["enc2"], c2),
        act("enc2_relu", ["enc2_in"]),
        conv_node("enc3", ["enc2_relu"], c3, c2, 3, 2),
        in_node("enc3_in", ["enc3"], c3),
        act("enc3_relu", ["enc3_in"]),
    ]
    prev = "enc3_relu"
    for b in range(3):
        nodes += [
            conv_node(f"res{b}_c1", [prev], c3, c3, 3),
            in_node(f"res{b}_in1", [f"res{b}_c1"], c3),
            act(f"res{b}_relu", [f"res{b}_in1"]),
            conv_node(f"res{b}_c2", [f"res{b}_relu"], c3, c3, 3),
            in_node(f"res{b}_in2", [f"res{b}_c2"], c3),
            {
                "name": f"res{b}_add",
                "op": "add",
                "inputs": [f"res{b}_in2", prev],
                "attrs": {},
            },
        ]
        prev = f"res{b}_add"
    nodes += [
        {"name": "up1", "op": "upsample", "inputs": [prev], "attrs": {"factor": 2}},
        conv_node("dec1", ["up1"], c2, c3, 3),
        in_node("dec1_in", ["dec1"], c2),
        act("dec1_relu", ["dec1_in"]),
        {"name": "up2", "op": "upsample", "inputs": ["dec1_relu"], "attrs": {"factor": 2}},
        conv_node("dec2", ["up2"], c1, c2, 3),
        in_node("dec2_in", ["dec2"], c1),
        act("dec2_relu", ["dec2_in"]),
        conv_node("dec3", ["dec2_relu"], 3, c1, 9),
        act("out_sigmoid", ["dec3"], "sigmoid"),
        {"name": "out", "op": "output", "inputs": ["out_sigmoid"], "attrs": {}},
    ]
    return nodes
