"""Layer-2 JAX model definitions (mirrors rust/src/apps/builders.rs)."""

from compile.models.blocks import conv2d, init_conv
from compile.models.coloring import coloring_forward, coloring_graph, init_coloring
from compile.models.style_transfer import init_style, style_forward, style_graph
from compile.models.super_resolution import init_sr, sr_forward, sr_graph

MODELS = {
    "style_transfer": (init_style, style_forward, style_graph),
    "coloring": (init_coloring, coloring_forward, coloring_graph),
    "super_resolution": (init_sr, sr_forward, sr_graph),
}

__all__ = [
    "MODELS",
    "conv2d",
    "init_conv",
    "init_style",
    "style_forward",
    "style_graph",
    "init_coloring",
    "coloring_forward",
    "coloring_graph",
    "init_sr",
    "sr_forward",
    "sr_graph",
]
