"""pytest bootstrap: make `compile.*` importable regardless of cwd."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
